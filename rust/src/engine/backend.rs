//! Prediction backends: the pluggable execution strategies behind the
//! [`Engine`](super::Engine) facade.
//!
//! * [`NativeScalar`] — wraps `model::predict`; the latency-optimal
//!   reference path, one row at a time, zero setup cost.
//! * [`NativeBatch`] — chunked scoped-thread evaluation for sweep-sized
//!   workloads (tokio/rayon are not in the offline vendor set —
//!   DESIGN.md "Offline substitutions"); bit-identical to
//!   `NativeScalar` row for row, deterministic output order.
//! * `Pjrt` (in [`super::pjrt`]) — the dynamically batched service over
//!   the AOT artifact executor.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use anyhow::Result;

use crate::model::soa::{SlabOut, SoaKernel};
use crate::model::{self, HwParams, KernelCounters, Regime};

/// Cumulative compute-side counters for span attribution (DESIGN.md
/// §13): every SoA slab evaluation the engine issues to a backend, and
/// the frequency points those slabs covered. Engine clones share one
/// instance; the serving layer snapshots before/after a handler runs
/// and charges the delta to that request's compute span. Approximate
/// under concurrency (two in-flight requests may claim each other's
/// slabs) — attribution, not accounting.
#[derive(Debug, Default)]
pub struct ComputeCounters {
    slab_calls: AtomicU64,
    points: AtomicU64,
}

impl ComputeCounters {
    /// Note one slab call covering `points` frequency points.
    pub fn note_slab(&self, points: usize) {
        self.slab_calls.fetch_add(1, Relaxed);
        self.points.fetch_add(points as u64, Relaxed);
    }

    pub fn snapshot(&self) -> ComputeStats {
        ComputeStats {
            slab_calls: self.slab_calls.load(Relaxed),
            points: self.points.load(Relaxed),
        }
    }
}

/// A point-in-time view of [`ComputeCounters`]; subtract two snapshots
/// to attribute work to an interval.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ComputeStats {
    pub slab_calls: u64,
    pub points: u64,
}

impl ComputeStats {
    /// Counter movement since an `earlier` snapshot.
    pub fn since(self, earlier: ComputeStats) -> ComputeStats {
        ComputeStats {
            slab_calls: self.slab_calls.saturating_sub(earlier.slab_calls),
            points: self.points.saturating_sub(earlier.points),
        }
    }
}

/// One prediction request: a profiled kernel at a frequency pair.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub counters: KernelCounters,
    pub core_mhz: f64,
    pub mem_mhz: f64,
}

/// Engine output for one request. Mirrors `model::Prediction`, with the
/// regime optional because opaque backends (the `Predictor` adapter)
/// cannot attribute a pipeline case.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Estimate {
    /// Cycles for one round of active warps (`T_active`).
    pub t_active: f64,
    /// Total kernel cycles in the core domain (`T_exec`).
    pub t_exec_cycles: f64,
    /// Wall-clock microseconds at the requested core frequency.
    pub time_us: f64,
    /// Pipeline case, when the backend can attribute one.
    pub regime: Option<Regime>,
}

impl From<model::Prediction> for Estimate {
    fn from(p: model::Prediction) -> Self {
        Estimate {
            t_active: p.t_active,
            t_exec_cycles: p.t_exec_cycles,
            time_us: p.time_us,
            regime: Some(p.regime),
        }
    }
}

/// A prediction execution strategy. Backends must be thread-safe: the
/// facade shares one instance across `predict_stream` workers, scoped
/// sweep threads and concurrent callers.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Evaluate every request, preserving order.
    fn predict_batch(&self, reqs: &[Request]) -> Result<Vec<Estimate>>;

    /// Single-request convenience (latency path).
    fn predict_one(&self, req: &Request) -> Result<Estimate> {
        let mut v = self.predict_batch(std::slice::from_ref(req))?;
        Ok(v.remove(0))
    }

    /// Evaluate one kernel over a frequency slab (`core_mhz[i]`,
    /// `mem_mhz[i]`), preserving order. Native backends route this
    /// through `model::soa` — per-kernel invariants hoisted once, no
    /// per-point struct walks. The default implementation expands to a
    /// request batch so opaque backends (the `Predictor` adapter, PJRT)
    /// stay correct without changes.
    fn predict_points(
        &self,
        counters: &KernelCounters,
        core_mhz: &[f64],
        mem_mhz: &[f64],
    ) -> Result<Vec<Estimate>> {
        assert_eq!(core_mhz.len(), mem_mhz.len());
        let reqs: Vec<Request> = core_mhz
            .iter()
            .zip(mem_mhz)
            .map(|(&cf, &mf)| Request { counters: *counters, core_mhz: cf, mem_mhz: mf })
            .collect();
        self.predict_batch(&reqs)
    }
}

/// Reassemble a SoA slab into the engine's row-major estimate form.
fn slab_to_estimates(slab: &SlabOut) -> Vec<Estimate> {
    (0..slab.len())
        .map(|i| Estimate {
            t_active: slab.t_active[i],
            t_exec_cycles: slab.t_exec_cycles[i],
            time_us: slab.time_us[i],
            regime: Some(slab.regime[i]),
        })
        .collect()
}

/// Direct scalar evaluation of the analytical model.
#[derive(Debug, Clone, Copy)]
pub struct NativeScalar {
    pub hw: HwParams,
}

impl NativeScalar {
    pub fn new(hw: HwParams) -> Self {
        NativeScalar { hw }
    }
}

impl Backend for NativeScalar {
    fn name(&self) -> &'static str {
        "native-scalar"
    }

    fn predict_batch(&self, reqs: &[Request]) -> Result<Vec<Estimate>> {
        Ok(reqs
            .iter()
            .map(|r| model::predict(&r.counters, &self.hw, r.core_mhz, r.mem_mhz).into())
            .collect())
    }

    fn predict_points(
        &self,
        counters: &KernelCounters,
        core_mhz: &[f64],
        mem_mhz: &[f64],
    ) -> Result<Vec<Estimate>> {
        let slab = SoaKernel::new(counters, &self.hw).predict(core_mhz, mem_mhz);
        Ok(slab_to_estimates(&slab))
    }
}

/// Scoped-thread chunked evaluation: splits the request slice into
/// contiguous chunks, one per worker, and writes each worker's results
/// straight into its own output window — no channels, no reordering, so
/// results are bit-identical to [`NativeScalar`] in the same order.
#[derive(Debug, Clone, Copy)]
pub struct NativeBatch {
    pub hw: HwParams,
    /// Maximum worker threads (clamped to the request count).
    pub workers: usize,
    /// Below this many requests the scalar loop is used — thread spawn
    /// costs more than evaluating a few rows.
    pub parallel_threshold: usize,
}

/// Crossover measured by the `engine_cache` bench: one model evaluation
/// is ~100 ns, a scoped spawn ~10 µs.
pub const DEFAULT_PARALLEL_THRESHOLD: usize = 256;

impl NativeBatch {
    pub fn new(hw: HwParams, workers: usize) -> Self {
        NativeBatch {
            hw,
            workers: workers.max(1),
            parallel_threshold: DEFAULT_PARALLEL_THRESHOLD,
        }
    }
}

impl Backend for NativeBatch {
    fn name(&self) -> &'static str {
        "native-batch"
    }

    fn predict_batch(&self, reqs: &[Request]) -> Result<Vec<Estimate>> {
        let workers = self.workers.min(reqs.len()).max(1);
        if workers == 1 || reqs.len() < self.parallel_threshold {
            return NativeScalar { hw: self.hw }.predict_batch(reqs);
        }
        let mut out = vec![Estimate::default(); reqs.len()];
        let chunk = reqs.len().div_ceil(workers);
        let hw = self.hw;
        std::thread::scope(|scope| {
            for (req_chunk, out_chunk) in reqs.chunks(chunk).zip(out.chunks_mut(chunk)) {
                scope.spawn(move || {
                    for (r, o) in req_chunk.iter().zip(out_chunk.iter_mut()) {
                        *o = model::predict(&r.counters, &hw, r.core_mhz, r.mem_mhz).into();
                    }
                });
            }
        });
        Ok(out)
    }

    fn predict_points(
        &self,
        counters: &KernelCounters,
        core_mhz: &[f64],
        mem_mhz: &[f64],
    ) -> Result<Vec<Estimate>> {
        assert_eq!(core_mhz.len(), mem_mhz.len());
        let n = core_mhz.len();
        let workers = self.workers.min(n).max(1);
        let kernel = SoaKernel::new(counters, &self.hw);
        if workers == 1 || n < self.parallel_threshold {
            return Ok(slab_to_estimates(&kernel.predict(core_mhz, mem_mhz)));
        }
        let mut out = vec![Estimate::default(); n];
        let chunk = n.div_ceil(workers);
        std::thread::scope(|scope| {
            for ((core_chunk, mem_chunk), out_chunk) in core_mhz
                .chunks(chunk)
                .zip(mem_mhz.chunks(chunk))
                .zip(out.chunks_mut(chunk))
            {
                let kernel = &kernel;
                scope.spawn(move || {
                    let slab = kernel.predict(core_chunk, mem_chunk);
                    for (o, e) in out_chunk.iter_mut().zip(slab_to_estimates(&slab)) {
                        *o = e;
                    }
                });
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn requests(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                counters: counters(),
                core_mhz: 400.0 + (i % 7) as f64 * 100.0,
                mem_mhz: 400.0 + (i / 7 % 7) as f64 * 100.0,
            })
            .collect()
    }

    #[test]
    fn compute_counters_accumulate_and_diff() {
        let c = ComputeCounters::default();
        let before = c.snapshot();
        c.note_slab(49);
        c.note_slab(7);
        let after = c.snapshot();
        assert_eq!(after, ComputeStats { slab_calls: 2, points: 56 });
        assert_eq!(after.since(before), ComputeStats { slab_calls: 2, points: 56 });
        assert_eq!(before.since(after), ComputeStats::default()); // saturates
    }

    #[test]
    fn scalar_matches_model() {
        let hw = HwParams::paper_defaults();
        let b = NativeScalar::new(hw);
        let reqs = requests(5);
        let out = b.predict_batch(&reqs).unwrap();
        for (o, r) in out.iter().zip(&reqs) {
            let want = model::predict(&r.counters, &hw, r.core_mhz, r.mem_mhz);
            assert_eq!(o.time_us.to_bits(), want.time_us.to_bits());
            assert_eq!(o.regime, Some(want.regime));
        }
    }

    #[test]
    fn batch_bit_identical_to_scalar_any_worker_count() {
        let hw = HwParams::paper_defaults();
        let reqs = requests(1000);
        let want = NativeScalar::new(hw).predict_batch(&reqs).unwrap();
        for workers in [1, 2, 3, 8] {
            let mut b = NativeBatch::new(hw, workers);
            b.parallel_threshold = 1; // force the threaded path
            let got = b.predict_batch(&reqs).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.time_us.to_bits(), w.time_us.to_bits(), "workers={workers}");
                assert_eq!(g.t_active.to_bits(), w.t_active.to_bits());
                assert_eq!(g.regime, w.regime);
            }
        }
    }

    #[test]
    fn small_batches_take_the_scalar_path() {
        let hw = HwParams::paper_defaults();
        let b = NativeBatch::new(hw, 8);
        let reqs = requests(3);
        let out = b.predict_batch(&reqs).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|e| e.time_us > 0.0));
    }

    #[test]
    fn slab_path_bit_identical_to_request_batch() {
        let hw = HwParams::paper_defaults();
        let c = counters();
        let reqs = requests(777);
        let core: Vec<f64> = reqs.iter().map(|r| r.core_mhz).collect();
        let mem: Vec<f64> = reqs.iter().map(|r| r.mem_mhz).collect();
        let want = NativeScalar::new(hw).predict_batch(&reqs).unwrap();
        // Scalar backend, SoA slab entry point.
        let got = NativeScalar::new(hw).predict_points(&c, &core, &mem).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.time_us.to_bits(), w.time_us.to_bits());
            assert_eq!(g.t_active.to_bits(), w.t_active.to_bits());
            assert_eq!(g.t_exec_cycles.to_bits(), w.t_exec_cycles.to_bits());
            assert_eq!(g.regime, w.regime);
        }
        // Threaded slab path, every worker count.
        for workers in [1, 2, 3, 8] {
            let mut b = NativeBatch::new(hw, workers);
            b.parallel_threshold = 1; // force the threaded path
            let got = b.predict_points(&c, &core, &mem).unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.time_us.to_bits(), w.time_us.to_bits(), "workers={workers}");
                assert_eq!(g.regime, w.regime);
            }
        }
    }

    #[test]
    fn default_trait_slab_impl_matches_batch() {
        // A backend that does not override predict_points must still be
        // correct through the request-expansion default.
        struct Opaque(NativeScalar);
        impl Backend for Opaque {
            fn name(&self) -> &'static str {
                "opaque"
            }
            fn predict_batch(&self, reqs: &[Request]) -> Result<Vec<Estimate>> {
                self.0.predict_batch(reqs)
            }
        }
        let hw = HwParams::paper_defaults();
        let c = counters();
        let core = [400.0, 700.0, 1000.0];
        let mem = [600.0, 600.0, 900.0];
        let got = Opaque(NativeScalar::new(hw)).predict_points(&c, &core, &mem).unwrap();
        for (i, g) in got.iter().enumerate() {
            let want = model::predict(&c, &hw, core[i], mem[i]);
            assert_eq!(g.time_us.to_bits(), want.time_us.to_bits());
        }
    }

    #[test]
    fn predict_one_default_impl() {
        let hw = HwParams::paper_defaults();
        let b = NativeScalar::new(hw);
        let r = requests(1)[0];
        let one = b.predict_one(&r).unwrap();
        let want = model::predict(&r.counters, &hw, r.core_mhz, r.mem_mhz);
        assert_eq!(one.time_us.to_bits(), want.time_us.to_bits());
    }
}
