//! Sharded, memoizing frequency-grid cache.
//!
//! The advisor, the sweep validator and the report emitters all query
//! the same (counters, hw) point over the same 49-pair grid, often
//! repeatedly within one process (advise → report → validate). The
//! cache makes every repeat free: a hit returns the stored [`Estimate`]
//! without touching the backend.
//!
//! **Key quantization (DESIGN.md §8):** every `f64` input — all 15
//! counter fields, the 7 hardware parameters and the two frequencies —
//! is quantized to its nearest `f32` and keyed on the f32 bit pattern.
//! f32 matches the AOT feature contract's precision, so two inputs that
//! the artifact could not distinguish share one entry; inputs differing
//! above f32 resolution never collide (bit-exact keys, no tolerance
//! comparisons).
//!
//! **Device identity (DESIGN.md §10):** keys additionally carry a
//! 64-bit device word — the `registry::DeviceId` for handle-path
//! lookups, [`ANONYMOUS_DEVICE`] for raw-struct calls — so two
//! registered GPUs can never collide on quantized frequency keys even
//! when their measured parameters agree at f32 resolution.
//!
//! **Sharding:** the key hash picks one of `shards` independent
//! `Mutex<FxHashMap>` segments, so concurrent engine clients (the
//! multi-worker PJRT service, `predict_stream`, scoped sweep threads)
//! do not serialize on one lock. Hit/miss counters are lock-free
//! atomics.

use std::hash::{BuildHasher, Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

use crate::model::{HwParams, KernelCounters};
use crate::util::fxhash::{FxBuildHasher, FxHashMap};

use super::Estimate;

/// Number of u32 words in a cache key: a 64-bit device-identity word
/// (split in two) + 15 counters + 7 hw params + core/mem MHz.
const KEY_WORDS: usize = 26;

/// Device-identity word for lookups made through the raw-struct path
/// (no registry handle). Registered devices use their `DeviceId` value,
/// which starts at 1.
pub const ANONYMOUS_DEVICE: u64 = 0;

/// Quantized lookup key (f32 bit patterns; see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey([u32; KEY_WORDS]);

#[inline]
fn q(x: f64) -> u32 {
    (x as f32).to_bits()
}

impl CacheKey {
    /// Key for the anonymous raw-struct path (no device identity).
    pub fn new(c: &KernelCounters, hw: &HwParams, core_mhz: f64, mem_mhz: f64) -> Self {
        Self::for_device(ANONYMOUS_DEVICE, c, hw, core_mhz, mem_mhz)
    }

    /// Key carrying a device identity word (DESIGN.md §10). Two
    /// registered devices never share an entry even when every numeric
    /// input quantizes to the same f32 words — device parameters that
    /// differ only below f32 resolution still produce different f64
    /// predictions, so identity must be part of the key.
    pub fn for_device(
        device: u64,
        c: &KernelCounters,
        hw: &HwParams,
        core_mhz: f64,
        mem_mhz: f64,
    ) -> Self {
        // Exhaustive destructuring (no `..`): adding a field to either
        // struct without extending the key is a compile error, never a
        // silent cache collision.
        let KernelCounters {
            l2_hr,
            gld_trans,
            avr_inst,
            n_blocks,
            wpb,
            aw,
            n_sm,
            o_itrs,
            i_itrs,
            uses_smem,
            smem_conflict,
            gld_body,
            gld_edge,
            mem_ops,
            l1_hr,
        } = *c;
        let HwParams {
            dm_lat_a,
            dm_lat_b,
            dm_del,
            l2_lat,
            l2_del,
            sh_lat,
            inst_cycle,
        } = *hw;
        CacheKey([
            (device >> 32) as u32,
            device as u32,
            q(l2_hr),
            q(gld_trans),
            q(avr_inst),
            q(n_blocks),
            q(wpb),
            q(aw),
            q(n_sm),
            q(o_itrs),
            q(i_itrs),
            if uses_smem { 1 } else { 0 },
            q(smem_conflict),
            q(gld_body),
            q(gld_edge),
            q(mem_ops),
            q(l1_hr),
            q(dm_lat_a),
            q(dm_lat_b),
            q(dm_del),
            q(l2_lat),
            q(l2_del),
            q(sh_lat),
            q(inst_cycle),
            q(core_mhz),
            q(mem_mhz),
        ])
    }
}

/// Monotonic cache counters plus current occupancy.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Shards wiped because they reached capacity.
    pub evictions: u64,
}

impl CacheStats {
    /// Hits / lookups in [0, 1]; 0 when no lookups have happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// The sharded memoization table.
pub struct GridCache {
    shards: Vec<Mutex<FxHashMap<CacheKey, Estimate>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    max_entries_per_shard: usize,
}

/// Default shard count: enough to keep a 16-worker service off a single
/// lock without wasting memory on small grids.
pub const DEFAULT_SHARDS: usize = 16;
/// Default per-shard capacity (≈1M entries total at 16 shards).
pub const DEFAULT_SHARD_CAPACITY: usize = 65_536;

impl Default for GridCache {
    fn default() -> Self {
        Self::new(DEFAULT_SHARDS, DEFAULT_SHARD_CAPACITY)
    }
}

impl GridCache {
    /// `shards` is clamped to at least 1; `max_entries_per_shard` bounds
    /// memory: a shard that reaches the bound is wiped whole (epoch
    /// eviction — cheap, and the working set re-warms in one grid pass).
    pub fn new(shards: usize, max_entries_per_shard: usize) -> Self {
        let shards = shards.max(1);
        GridCache {
            shards: (0..shards).map(|_| Mutex::new(FxHashMap::default())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            max_entries_per_shard: max_entries_per_shard.max(1),
        }
    }

    #[inline]
    fn shard_of(&self, key: &CacheKey) -> usize {
        let mut h = FxBuildHasher::default().build_hasher();
        key.hash(&mut h);
        // Pick the shard from the HIGH bits: the HashMap inside the
        // shard indexes buckets with the low bits of this same hash,
        // so folding low bits into the shard choice would cluster a
        // shard's keys into 1/shards of its bucket space.
        ((h.finish() >> 48) as usize) % self.shards.len()
    }

    /// Look up one key, counting the hit or miss.
    pub fn get(&self, key: &CacheKey) -> Option<Estimate> {
        let shard = self.shards[self.shard_of(key)].lock().expect("cache shard poisoned");
        let found = shard.get(key).copied();
        drop(shard);
        match found {
            Some(e) => {
                self.hits.fetch_add(1, Relaxed);
                Some(e)
            }
            None => {
                self.misses.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// Insert (idempotent — later inserts of the same key overwrite with
    /// an identical value by construction).
    pub fn insert(&self, key: CacheKey, est: Estimate) {
        let mut shard = self.shards[self.shard_of(&key)].lock().expect("cache shard poisoned");
        if shard.len() >= self.max_entries_per_shard && !shard.contains_key(&key) {
            shard.clear();
            self.evictions.fetch_add(1, Relaxed);
        }
        shard.insert(key, est);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Relaxed),
            misses: self.misses.load(Relaxed),
            entries: self
                .shards
                .iter()
                .map(|s| s.lock().expect("cache shard poisoned").len())
                .sum(),
            evictions: self.evictions.load(Relaxed),
        }
    }

    /// Drop every entry (counters are preserved).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().expect("cache shard poisoned").clear();
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Regime;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.25,
            gld_trans: 4.0,
            avr_inst: 2.0,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        }
    }

    fn est(t: f64) -> Estimate {
        Estimate {
            t_active: t,
            t_exec_cycles: 2.0 * t,
            time_us: t / 700.0,
            regime: Some(Regime::Memory),
        }
    }

    #[test]
    fn hit_after_insert_and_stats_count() {
        let cache = GridCache::default();
        let hw = HwParams::paper_defaults();
        let k = CacheKey::new(&counters(), &hw, 700.0, 700.0);
        assert!(cache.get(&k).is_none());
        cache.insert(k, est(10.0));
        assert_eq!(cache.get(&k), Some(est(10.0)));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_frequencies_distinct_keys() {
        let hw = HwParams::paper_defaults();
        let c = counters();
        let a = CacheKey::new(&c, &hw, 700.0, 700.0);
        let b = CacheKey::new(&c, &hw, 700.0, 800.0);
        assert_ne!(a, b);
    }

    #[test]
    fn sub_f32_differences_share_a_key() {
        // Quantization contract: differences below f32 resolution
        // collapse into one entry (the AOT artifact could not tell the
        // two inputs apart either).
        let hw = HwParams::paper_defaults();
        let mut c2 = counters();
        c2.avr_inst += 1e-12;
        assert_eq!(
            CacheKey::new(&counters(), &hw, 700.0, 700.0),
            CacheKey::new(&c2, &hw, 700.0, 700.0)
        );
    }

    #[test]
    fn hw_params_are_part_of_the_key() {
        let c = counters();
        let a = CacheKey::new(&c, &HwParams::paper_defaults(), 700.0, 700.0);
        let mut hw = HwParams::paper_defaults();
        hw.dm_del += 1.0;
        assert_ne!(a, CacheKey::new(&c, &hw, 700.0, 700.0));
    }

    #[test]
    fn device_identity_is_part_of_the_key() {
        // Regression (DESIGN.md §10): two registered devices must never
        // share an entry, even when their numeric inputs are identical
        // after f32 quantization.
        let hw = HwParams::paper_defaults();
        let c = counters();
        let anon = CacheKey::new(&c, &hw, 700.0, 700.0);
        let dev1 = CacheKey::for_device(1, &c, &hw, 700.0, 700.0);
        let dev2 = CacheKey::for_device(2, &c, &hw, 700.0, 700.0);
        assert_eq!(anon, CacheKey::for_device(ANONYMOUS_DEVICE, &c, &hw, 700.0, 700.0));
        assert_ne!(anon, dev1);
        assert_ne!(dev1, dev2);
        // High device-id bits are not truncated away.
        assert_ne!(
            CacheKey::for_device(1, &c, &hw, 700.0, 700.0),
            CacheKey::for_device(1 | (1 << 32), &c, &hw, 700.0, 700.0)
        );
    }

    #[test]
    fn capacity_bound_evicts_by_epoch() {
        let cache = GridCache::new(1, 4);
        let hw = HwParams::paper_defaults();
        for i in 0..10 {
            let k = CacheKey::new(&counters(), &hw, 400.0 + i as f64, 700.0);
            cache.insert(k, est(i as f64));
        }
        let s = cache.stats();
        assert!(s.entries <= 4, "entries {}", s.entries);
        assert!(s.evictions >= 1);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = std::sync::Arc::new(GridCache::new(8, 1024));
        let hw = HwParams::paper_defaults();
        let mut joins = Vec::new();
        for t in 0..8u32 {
            let cache = cache.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..200u32 {
                    let k =
                        CacheKey::new(&counters(), &hw, 400.0 + (i % 32) as f64, 400.0 + t as f64);
                    if cache.get(&k).is_none() {
                        cache.insert(k, est(i as f64));
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 8 * 200);
        assert!(s.entries <= 8 * 32);
    }
}
