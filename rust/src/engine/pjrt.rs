//! The PJRT-backed prediction service: dynamic batching over the AOT
//! artifact executor, upgraded from the original single drain worker
//! (formerly `coordinator/batcher.rs`) to **N workers over
//! sharded request queues**.
//!
//! Requests are spread round-robin across per-worker mpsc queues; each
//! worker drains up to a full `PREDICT_BATCH` (or until `max_wait`
//! passes with a partial batch), executes one runtime call, and fans
//! the rows back to the waiting clients. Sharding removes the
//! single-queue bottleneck: with W workers, W batches execute
//! concurrently and queue contention is 1/W of the single-lane design.

use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::params::{N_FEATURES, N_HW_PARAMS, N_OUTPUTS};
use crate::model::{KernelCounters, Regime};
use crate::runtime::{Runtime, PREDICT_BATCH};

/// A decoded prediction row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPrediction {
    pub t_active: f64,
    pub t_exec_cycles: f64,
    pub time_us: f64,
    pub regime: Option<Regime>,
}

impl BatchPrediction {
    fn from_row(row: [f32; N_OUTPUTS]) -> Self {
        BatchPrediction {
            t_active: row[0] as f64,
            t_exec_cycles: row[1] as f64,
            time_us: row[2] as f64,
            regime: Regime::from_id(row[3] as u32),
        }
    }
}

struct Request {
    features: [f32; N_FEATURES],
    resp: Sender<BatchPrediction>,
}

/// Counters the service exposes (all monotonically increasing).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: std::sync::atomic::AtomicU64,
    pub batches: std::sync::atomic::AtomicU64,
    pub rows_padded: std::sync::atomic::AtomicU64,
}

impl ServerStats {
    pub fn requests(&self) -> u64 {
        self.requests.load(std::sync::atomic::Ordering::Relaxed)
    }
    pub fn batches(&self) -> u64 {
        self.batches.load(std::sync::atomic::Ordering::Relaxed)
    }
    pub fn rows_padded(&self) -> u64 {
        self.rows_padded.load(std::sync::atomic::Ordering::Relaxed)
    }
    /// Mean occupancy of executed batches in [0, 1].
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            return 0.0;
        }
        let total_rows = b * PREDICT_BATCH as u64;
        (total_rows - self.rows_padded()) as f64 / total_rows as f64
    }
}

/// Handle to the batching service. Cloneable and `Sync`; dropping every
/// handle shuts the workers down.
#[derive(Clone)]
pub struct BatchServer {
    shards: Arc<Vec<Mutex<Sender<Request>>>>,
    next: Arc<AtomicUsize>,
    stats: Arc<ServerStats>,
    platform: String,
}

fn worker_loop(
    runtime: Runtime,
    hw: [f32; N_HW_PARAMS],
    rx: Receiver<Request>,
    max_wait: Duration,
    stats: Arc<ServerStats>,
) {
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        while pending.len() < PREDICT_BATCH {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        let rows: Vec<[f32; N_FEATURES]> = pending.iter().map(|r| r.features).collect();
        stats.requests.fetch_add(rows.len() as u64, Relaxed);
        stats.batches.fetch_add(1, Relaxed);
        let padded = (PREDICT_BATCH - rows.len() % PREDICT_BATCH) % PREDICT_BATCH;
        stats.rows_padded.fetch_add(padded as u64, Relaxed);

        match runtime.predict(&rows, &hw) {
            Ok(out) => {
                for (req, row) in pending.into_iter().zip(out) {
                    let _ = req.resp.send(BatchPrediction::from_row(row));
                }
            }
            Err(e) => {
                // Drop the response senders: clients see RecvError.
                eprintln!("batch execution failed: {e:#}");
            }
        }
    }
}

fn spawn_worker<F>(
    factory: F,
    hw: [f32; N_HW_PARAMS],
    max_wait: Duration,
    rx: Receiver<Request>,
    stats: Arc<ServerStats>,
    init_tx: Sender<Result<String>>,
) -> JoinHandle<()>
where
    F: FnOnce() -> Result<Runtime> + Send + 'static,
{
    std::thread::spawn(move || {
        // The real PJRT client is not `Send` (it holds an `Rc`
        // internally), so each worker constructs its own Runtime; init
        // errors are surfaced synchronously through `init_tx`.
        let runtime = match factory() {
            Ok(rt) => {
                let _ = init_tx.send(Ok(rt.platform()));
                rt
            }
            Err(e) => {
                let _ = init_tx.send(Err(e));
                return;
            }
        };
        worker_loop(runtime, hw, rx, max_wait, stats);
    })
}

impl BatchServer {
    /// Start a single-worker service (the original batcher topology).
    pub fn start<F>(
        factory: F,
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
    ) -> Result<(Self, Vec<JoinHandle<()>>)>
    where
        F: FnOnce() -> Result<Runtime> + Send + 'static,
    {
        let stats = Arc::new(ServerStats::default());
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel();
        let handle = spawn_worker(factory, hw, max_wait, rx, stats.clone(), init_tx);
        let platform = init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batch worker died during init"))??;
        Ok((
            BatchServer {
                shards: Arc::new(vec![Mutex::new(tx)]),
                next: Arc::new(AtomicUsize::new(0)),
                stats,
                platform,
            },
            vec![handle],
        ))
    }

    /// Start `workers` drain workers over sharded request queues.
    pub fn start_sharded<F>(
        factory: F,
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
        workers: usize,
    ) -> Result<(Self, Vec<JoinHandle<()>>)>
    where
        F: Fn() -> Result<Runtime> + Clone + Send + 'static,
    {
        let workers = workers.max(1);
        let stats = Arc::new(ServerStats::default());
        let (init_tx, init_rx) = mpsc::channel();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel::<Request>();
            senders.push(Mutex::new(tx));
            handles.push(spawn_worker(
                factory.clone(),
                hw,
                max_wait,
                rx,
                stats.clone(),
                init_tx.clone(),
            ));
        }
        drop(init_tx);
        let mut platform = String::new();
        for _ in 0..workers {
            platform = init_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("batch worker died during init"))??;
        }
        Ok((
            BatchServer {
                shards: Arc::new(senders),
                next: Arc::new(AtomicUsize::new(0)),
                stats,
                platform,
            },
            handles,
        ))
    }

    /// Start a single worker against the default artifacts directory
    /// (fails without artifacts — see [`Runtime::load`]).
    pub fn start_default(
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
    ) -> Result<(Self, Vec<JoinHandle<()>>)> {
        Self::start(Runtime::load_default, hw, max_wait)
    }

    /// Start `workers` workers on the always-available emulated executor.
    pub fn start_emulated(
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
        workers: usize,
    ) -> Result<(Self, Vec<JoinHandle<()>>)> {
        Self::start_sharded(|| Ok(Runtime::emulated()), hw, max_wait, workers)
    }

    /// Artifacts when present, emulation otherwise — the production
    /// entry point (`gpufreq serve`, `--backend pjrt`).
    pub fn start_auto(
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
        workers: usize,
    ) -> Result<(Self, Vec<JoinHandle<()>>)> {
        Self::start_sharded(|| Ok(Runtime::load_or_emulated()), hw, max_wait, workers)
    }

    /// PJRT platform name the workers run on.
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Number of request shards (= workers).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn enqueue(&self, features: [f32; N_FEATURES]) -> Result<mpsc::Receiver<BatchPrediction>> {
        let (resp, rx) = mpsc::channel();
        let shard = self.next.fetch_add(1, Relaxed) % self.shards.len();
        self.shards[shard]
            .lock()
            .expect("request shard poisoned")
            .send(Request { features, resp })
            .map_err(|_| anyhow::anyhow!("batch server stopped"))?;
        Ok(rx)
    }

    /// Blocking single prediction (latency path).
    pub fn predict(
        &self,
        counters: &KernelCounters,
        core_mhz: f64,
        mem_mhz: f64,
    ) -> Result<BatchPrediction> {
        let rx = self.enqueue(counters.to_features(core_mhz, mem_mhz))?;
        rx.recv().map_err(|_| anyhow::anyhow!("batch execution failed"))
    }

    /// Blocking many-row prediction (throughput path): enqueues every
    /// row across the shards before draining responses, so rows share
    /// batches and workers run concurrently.
    pub fn predict_features(
        &self,
        rows: &[[f32; N_FEATURES]],
    ) -> Result<Vec<BatchPrediction>> {
        let rxs: Result<Vec<_>> = rows.iter().map(|r| self.enqueue(*r)).collect();
        rxs?.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("batch execution failed")))
            .collect()
    }

    /// Blocking grid prediction for one kernel profile.
    pub fn predict_grid(
        &self,
        counters: &KernelCounters,
        pairs: &[(f64, f64)],
    ) -> Result<Vec<BatchPrediction>> {
        let rows: Vec<[f32; N_FEATURES]> =
            pairs.iter().map(|&(cf, mf)| counters.to_features(cf, mf)).collect();
        self.predict_features(&rows)
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }
}

/// [`Backend`](super::Backend) over the batching service.
pub struct PjrtBackend {
    server: BatchServer,
}

impl PjrtBackend {
    pub fn new(server: BatchServer) -> Self {
        PjrtBackend { server }
    }

    pub fn server(&self) -> &BatchServer {
        &self.server
    }
}

impl super::Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn predict_batch(&self, reqs: &[super::Request]) -> Result<Vec<super::Estimate>> {
        let rows: Vec<[f32; N_FEATURES]> =
            reqs.iter().map(|r| r.counters.to_features(r.core_mhz, r.mem_mhz)).collect();
        let out = self.server.predict_features(&rows)?;
        Ok(out
            .into_iter()
            .map(|p| super::Estimate {
                t_active: p.t_active,
                t_exec_cycles: p.t_exec_cycles,
                time_us: p.time_us,
                regime: p.regime,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{self, HwParams};

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn single_and_grid_predictions_match_native() {
        let hw = HwParams::paper_defaults();
        let (server, _h) =
            BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(2), 1).unwrap();
        assert!(server.platform().to_lowercase().contains("cpu"));
        let c = counters();

        let one = server.predict(&c, 700.0, 700.0).unwrap();
        let native = model::predict(&c, &hw, 700.0, 700.0);
        assert!((one.time_us - native.time_us).abs() / native.time_us < 1e-4);
        assert_eq!(one.regime, Some(native.regime));

        let grid = crate::microbench::standard_grid();
        let out = server.predict_grid(&c, &grid).unwrap();
        assert_eq!(out.len(), 49);
        for (p, &(cf, mf)) in out.iter().zip(&grid) {
            let n = model::predict(&c, &hw, cf, mf);
            assert!(
                (p.time_us - n.time_us).abs() / n.time_us < 1e-4,
                "({cf},{mf}): {} vs {}",
                p.time_us,
                n.time_us
            );
        }
        assert!(server.stats().requests() >= 50);
        assert!(server.stats().batches() >= 1);
        assert!(server.stats().mean_occupancy() > 0.0);
    }

    #[test]
    fn sharded_workers_cover_the_grid() {
        let hw = HwParams::paper_defaults();
        let (server, handles) =
            BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(2), 4).unwrap();
        assert_eq!(server.shard_count(), 4);
        assert_eq!(handles.len(), 4);
        let c = counters();
        let grid = crate::microbench::standard_grid();
        let out = server.predict_grid(&c, &grid).unwrap();
        assert_eq!(out.len(), 49);
        for (p, &(cf, mf)) in out.iter().zip(&grid) {
            let n = model::predict(&c, &hw, cf, mf);
            assert!((p.time_us - n.time_us).abs() / n.time_us < 1e-4);
        }
        assert_eq!(server.stats().requests(), 49);
    }

    #[test]
    fn concurrent_clients_are_all_served() {
        let hw = HwParams::paper_defaults();
        let (server, _h) =
            BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(5), 2).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            let c = counters();
            joins.push(std::thread::spawn(move || {
                let cf = 400.0 + (t as f64) * 50.0;
                let p = s.predict(&c, cf, 700.0).unwrap();
                assert!(p.time_us > 0.0);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let st = server.stats();
        assert_eq!(st.requests(), 8);
        // Batching must not inflate the batch count past the request count.
        assert!(st.batches() <= 8);
    }

    #[test]
    fn start_default_requires_artifacts() {
        // From a clean checkout there are no AOT artifacts, so the
        // artifact-pinned constructor must fail with actionable context;
        // with artifacts present it must come up on a CPU platform.
        let hw = HwParams::paper_defaults().to_f32();
        match BatchServer::start_default(hw, Duration::from_millis(1)) {
            Ok((server, _h)) => assert!(server.platform().to_lowercase().contains("cpu")),
            Err(e) => assert!(format!("{e:#}").contains("make artifacts")),
        }
    }
}
