//! Micro-benchmark suite (the paper's §IV, after Mei & Chu [31]):
//! P-chase-style latency probes, a saturating bandwidth probe, shared
//! memory and instruction-cost probes — all executed **on the
//! simulator**, exactly the way the paper runs them on silicon, so the
//! model's hardware parameters are *measured*, never copied from the
//! simulator's config.

use crate::model::fit::{fit_line, LineFit};
use crate::model::HwParams;
use crate::sim::engine::simulate;
use crate::sim::isa::{Addressing, Kernel, Launch, MemPat, Op, Program};
use crate::sim::{Clocks, GpuSpec};

/// Outcome of the saturating-bandwidth probe at one frequency pair.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthProbe {
    /// Measured per-channel service interval, memory cycles (`dm_del`).
    pub dm_del_mem_cycles: f64,
    /// Measured per-channel service interval, core cycles.
    pub dm_del_core_cycles: f64,
    /// Achieved / theoretical-burst bandwidth (Table III "efficiency").
    pub efficiency: f64,
    /// Achieved DRAM bandwidth, GB/s.
    pub achieved_gbps: f64,
}

fn single_warp_kernel(body: Vec<Op>, o_itrs: u32) -> Kernel {
    Kernel::new(
        "probe",
        Launch::new(1, 32),
        Program { prologue: vec![], body, o_itrs, epilogue: vec![] },
    )
}

/// Per-access elapsed core cycles of a single-warp probe, with launch
/// overhead removed.
fn per_access_core_cycles(spec: &GpuSpec, clocks: Clocks, kernel: &Kernel, accesses: f64) -> f64 {
    let r = simulate(spec, clocks, kernel);
    let cycles = r.stats.elapsed_core_cycles(clocks.core_mhz) - spec.block_launch_core_cycles;
    cycles / accesses
}

/// Unloaded DRAM latency in core cycles (the paper's `dm_lat` probe:
/// one warp, dependent accesses, footprint too big to cache).
pub fn dram_latency_probe(spec: &GpuSpec, clocks: Clocks) -> f64 {
    let o = 400;
    let k = single_warp_kernel(
        vec![Op::Load(MemPat::new(1, Addressing::OwnLinear, 9))],
        o,
    );
    per_access_core_cycles(spec, clocks, &k, o as f64)
}

/// L2 hit latency in core cycles (hot footprint that fits in L2).
pub fn l2_latency_probe(spec: &GpuSpec, clocks: Clocks) -> f64 {
    let o = 4000;
    let k = single_warp_kernel(
        vec![Op::Load(MemPat::new(1, Addressing::Hot { lines: 64 }, 9))],
        o,
    );
    per_access_core_cycles(spec, clocks, &k, o as f64)
}

/// Texture/L1 hit latency in core cycles (hot footprint that fits the
/// per-SM L1; §VII future-work extension).
pub fn l1_latency_probe(spec: &GpuSpec, clocks: Clocks) -> f64 {
    let o = 4000;
    let k = single_warp_kernel(
        vec![Op::Load(MemPat::new(1, Addressing::Hot { lines: 64 }, 9).through_l1())],
        o,
    );
    per_access_core_cycles(spec, clocks, &k, o as f64)
}

/// Shared-memory latency in core cycles.
pub fn smem_latency_probe(spec: &GpuSpec, clocks: Clocks) -> f64 {
    let o = 1000;
    let k = single_warp_kernel(vec![Op::SharedLoad { conflict: 1 }], o);
    per_access_core_cycles(spec, clocks, &k, o as f64)
}

/// Per-instruction issue cost in core cycles (`inst_cycle`).
pub fn inst_cycle_probe(spec: &GpuSpec, clocks: Clocks) -> f64 {
    let o = 2000;
    let k = single_warp_kernel(vec![Op::Compute(1)], o);
    per_access_core_cycles(spec, clocks, &k, o as f64)
}

/// Saturating bandwidth probe: fill every SM with streaming warps and
/// infer `dm_del` per the paper's Eq. (3):
/// `T = dm_lat + dm_del * gld_trans * #W` (per channel).
pub fn bandwidth_probe(spec: &GpuSpec, clocks: Clocks) -> BandwidthProbe {
    let blocks = spec.n_sm * 8;
    let o_itrs = 32;
    let k = Kernel::new(
        "bwprobe",
        Launch::new(blocks, 256),
        Program {
            prologue: vec![],
            body: vec![Op::Load(MemPat::new(4, Addressing::OwnLinear, 9))],
            o_itrs,
            epilogue: vec![],
        },
    );
    let r = simulate(spec, clocks, &k);
    let dm_lat_ns =
        spec.dm_path_core_cycles * clocks.core_ns() + spec.dm_access_mem_cycles * clocks.mem_ns();
    let txns_per_channel = r.stats.dram_txns as f64 / r.stats.active_sms.max(1) as f64;
    let dm_del_ns = (r.stats.elapsed_ns - dm_lat_ns) / txns_per_channel;
    let dm_del_mem_cycles = dm_del_ns / clocks.mem_ns();
    let burst_ns = spec.dm_burst_mem_cycles * clocks.mem_ns();
    BandwidthProbe {
        dm_del_mem_cycles,
        dm_del_core_cycles: dm_del_ns / clocks.core_ns(),
        efficiency: burst_ns / dm_del_ns,
        achieved_gbps: r.stats.dram_bandwidth(spec.line_bytes),
    }
}

/// A full Eq. (4) sweep: measure `dm_lat` at every frequency pair in
/// `pairs` and return (ratios, latencies in core cycles).
pub fn dm_lat_sweep(spec: &GpuSpec, pairs: &[(f64, f64)]) -> (Vec<f64>, Vec<f64>) {
    let mut ratios = Vec::with_capacity(pairs.len());
    let mut lats = Vec::with_capacity(pairs.len());
    for &(cf, mf) in pairs {
        let clocks = Clocks::new(cf, mf);
        ratios.push(clocks.ratio());
        lats.push(dram_latency_probe(spec, clocks));
    }
    (ratios, lats)
}

/// The standard 49-pair grid (400–1000 MHz × 400–1000 MHz, 100 MHz
/// stride) the paper sweeps.
pub fn standard_grid() -> Vec<(f64, f64)> {
    let steps: Vec<f64> = (4..=10).map(|i| i as f64 * 100.0).collect();
    let mut out = Vec::with_capacity(49);
    for &cf in &steps {
        for &mf in &steps {
            out.push((cf, mf));
        }
    }
    out
}

/// Everything `extract` measures, with provenance.
#[derive(Debug, Clone)]
pub struct Extraction {
    pub hw: HwParams,
    pub dm_lat_fit: LineFit,
    /// (ratio, latency) samples behind the fit.
    pub dm_lat_samples: Vec<(f64, f64)>,
    pub bandwidth_at_baseline: BandwidthProbe,
}

/// The paper's full §IV extraction: sweep `dm_lat` over the 49-pair
/// grid, fit Eq. (4), and probe everything else at the baseline.
pub fn extract(spec: &GpuSpec, baseline: Clocks) -> Extraction {
    let pairs = standard_grid();
    let (ratios, lats) = dm_lat_sweep(spec, &pairs);
    let fitted = fit_line(&ratios, &lats);
    let bw = bandwidth_probe(spec, baseline);
    let hw = HwParams {
        dm_lat_a: fitted.slope,
        dm_lat_b: fitted.intercept,
        dm_del: bw.dm_del_mem_cycles,
        l2_lat: l2_latency_probe(spec, baseline),
        // Table IV: l2_del comes from the hardware specification.
        l2_del: spec.l2_ii_core_cycles,
        sh_lat: smem_latency_probe(spec, baseline),
        inst_cycle: inst_cycle_probe(spec, baseline),
    };
    Extraction {
        hw,
        dm_lat_fit: fitted,
        dm_lat_samples: ratios.into_iter().zip(lats).collect(),
        bandwidth_at_baseline: bw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GpuSpec {
        GpuSpec::default()
    }

    #[test]
    fn dram_latency_tracks_eq4() {
        let s = spec();
        for (cf, mf) in [(400.0, 400.0), (1000.0, 400.0), (400.0, 1000.0)] {
            let lat = dram_latency_probe(&s, Clocks::new(cf, mf));
            let eq4 = s.dm_access_mem_cycles * (cf / mf) + s.dm_path_core_cycles;
            assert!((lat - eq4).abs() / eq4 < 0.06, "cf={cf} mf={mf}: {lat} vs {eq4}");
        }
    }

    #[test]
    fn l2_latency_near_spec_and_flat() {
        let s = spec();
        let a = l2_latency_probe(&s, Clocks::new(700.0, 400.0));
        let b = l2_latency_probe(&s, Clocks::new(700.0, 1000.0));
        assert!((a - s.l2_hit_core_cycles).abs() / s.l2_hit_core_cycles < 0.10, "{a}");
        assert!((a - b).abs() / a < 0.05);
    }

    #[test]
    fn l1_latency_probe_near_spec() {
        let s = spec();
        let lat = l1_latency_probe(&s, Clocks::new(700.0, 700.0));
        assert!((lat - s.l1_hit_core_cycles).abs() / s.l1_hit_core_cycles < 0.12, "{lat}");
        // And flat in memory frequency (core-clocked component).
        let b = l1_latency_probe(&s, Clocks::new(700.0, 400.0));
        assert!((lat - b).abs() / lat < 0.05);
    }

    #[test]
    fn smem_and_inst_probes() {
        let s = spec();
        let sh = smem_latency_probe(&s, Clocks::new(700.0, 700.0));
        assert!((sh - s.smem_core_cycles).abs() < 1.0, "{sh}");
        let ic = inst_cycle_probe(&s, Clocks::new(700.0, 700.0));
        assert!((ic - s.inst_core_cycles).abs() < 0.1, "{ic}");
    }

    #[test]
    fn bandwidth_probe_extracts_dm_del() {
        let s = spec();
        let bw = bandwidth_probe(&s, Clocks::new(700.0, 700.0));
        // Burst floor is 8; row misses push it up but not past ~10.
        assert!(
            bw.dm_del_mem_cycles > s.dm_burst_mem_cycles
                && bw.dm_del_mem_cycles < s.dm_burst_mem_cycles + 2.0,
            "dm_del {}",
            bw.dm_del_mem_cycles
        );
        assert!(bw.efficiency > 0.7 && bw.efficiency < 1.0, "eff {}", bw.efficiency);
    }

    #[test]
    fn dm_del_scales_with_ratio_in_core_cycles() {
        // Eq. (5b): in core cycles dm_del scales by cf/mf.
        let s = spec();
        let a = bandwidth_probe(&s, Clocks::new(1000.0, 400.0));
        let b = bandwidth_probe(&s, Clocks::new(1000.0, 1000.0));
        let ratio = a.dm_del_core_cycles / b.dm_del_core_cycles;
        assert!((ratio - 2.5).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn standard_grid_is_49_pairs() {
        let g = standard_grid();
        assert_eq!(g.len(), 49);
        assert_eq!(g[0], (400.0, 400.0));
        assert_eq!(g[48], (1000.0, 1000.0));
    }

    #[test]
    fn extraction_fit_matches_paper_line() {
        let s = spec();
        let e = extract(&s, Clocks::new(700.0, 700.0));
        // The simulator is calibrated to the paper's Eq. (4); the probe
        // must recover it through measurement.
        assert!((e.dm_lat_fit.slope - 222.78).abs() < 8.0, "slope {}", e.dm_lat_fit.slope);
        assert!(
            (e.dm_lat_fit.intercept - 277.32).abs() < 8.0,
            "intercept {}",
            e.dm_lat_fit.intercept
        );
        assert!(e.dm_lat_fit.r_squared > 0.99, "r2 {}", e.dm_lat_fit.r_squared);
        assert_eq!(e.dm_lat_samples.len(), 49);
        assert!(e.hw.l2_del == s.l2_ii_core_cycles);
    }
}
