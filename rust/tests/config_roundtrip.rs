//! Config serializer round-trip and `[power.*]` rejection suite.
//!
//! Two halves:
//!
//! * both shipped `configs/*.toml` files survive a full
//!   parse → `to_text` → re-parse cycle with `Config` equality — the
//!   serializer is the inverse of the parser on real calibrations, so
//!   `gpufreq devices` snapshots and hand-edited files never drift;
//! * every malformed `[power]` / `[power.dynamic]` / `[power.leakage]`
//!   shape is rejected with its exact, documented error message —
//!   mistyped calibrations are hard errors, never silent defaults.

use std::path::Path;

use gpufreq::config::{from_text, load, to_text};
use gpufreq::dvfs::PowerModel;

fn config_path(name: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("configs").join(name)
}

#[test]
fn shipped_configs_round_trip_through_to_text() {
    for name in ["gtx980.toml", "gtx960.toml"] {
        let cfg = load(&config_path(name)).unwrap_or_else(|e| panic!("loading {name}: {e}"));
        let text = to_text(&cfg);
        let again = from_text(&text)
            .unwrap_or_else(|e| panic!("re-parsing serialized {name}: {e}"));
        assert_eq!(again, cfg, "{name}: to_text -> from_text changed the config");
        // And the cycle is a fixed point: serializing the re-parsed
        // config reproduces the same text, byte for byte.
        assert_eq!(to_text(&again), text, "{name}: second serialization differs");
    }
}

#[test]
fn gtx980_config_carries_the_builtin_calibration() {
    let cfg = load(&config_path("gtx980.toml")).unwrap();
    assert_eq!(cfg.power, PowerModel::gtx980());
}

#[test]
fn gtx960_power_differs_from_gtx980() {
    // The second shipped calibration must be a real second data point,
    // not a copy — otherwise the round-trip test above proves less.
    let a = load(&config_path("gtx980.toml")).unwrap();
    let b = load(&config_path("gtx960.toml")).unwrap();
    assert_ne!(a.power, b.power, "shipped calibrations should differ");
}

/// Assert that `snippet` fails to parse with exactly `want` as the
/// error message (the `line 0` prefix is the power layer's synthetic
/// line; `message` carries the real diagnosis).
fn rejects(snippet: &str, want: &str) {
    match from_text(snippet) {
        Ok(_) => panic!("accepted malformed config:\n{snippet}"),
        Err(e) => assert_eq!(
            e.message, want,
            "wrong error for:\n{snippet}\n  got:  {}\n  want: {want}",
            e.message
        ),
    }
}

#[test]
fn unknown_power_keys_are_rejected() {
    rejects("[power]\nwattage = 9\n", "unknown power key `power.wattage`");
    rejects("[power.dynamic]\ngain = 1\n", "unknown power key `power.dynamic.gain`");
    rejects("[power.leakage]\nalpha = 2\n", "unknown power key `power.leakage.alpha`");
}

#[test]
fn legacy_and_v2_spellings_conflict() {
    rejects(
        "[power]\ncore_coeff = 0.05\n[power.dynamic]\ncore_coeff = 0.06\n",
        "`power.core_coeff` conflicts with `power.dynamic.core_coeff`: set one",
    );
    rejects(
        "[power]\nmem_coeff = 0.01\n[power.dynamic]\nmem_coeff = 0.02\n",
        "`power.mem_coeff` conflicts with `power.dynamic.mem_coeff`: set one",
    );
    rejects(
        "[power]\nstatic_w = 8\n[power.leakage]\nstatic_w = 9\n",
        "`power.static_w` conflicts with `power.leakage.static_w`: set one",
    );
}

#[test]
fn mistyped_numbers_are_rejected() {
    rejects("[power]\nstatic_w = \"big\"\n", "power.static_w: expected a number");
    rejects("[power.leakage]\nv_slope = true\n", "power.leakage.v_slope: expected a number");
    rejects("[power.leakage]\nv_ref = inf\n", "power.leakage.v_ref: must be finite, got inf");
}

#[test]
fn out_of_range_numbers_are_rejected() {
    rejects("[power]\nstatic_w = -3\n", "power.static_w: must be >= 0, got -3");
    rejects(
        "[power.dynamic]\ncore_coeff = -0.25\n",
        "power.dynamic.core_coeff: must be >= 0, got -0.25",
    );
    rejects("[power.leakage]\nleak_w = -1\n", "power.leakage.leak_w: must be >= 0, got -1");
    rejects("[power.leakage]\nv_ref = 0\n", "power.leakage.v_ref: must be > 0, got 0");
    rejects(
        "[power.leakage]\nv_slope = -0.5\n",
        "power.leakage.v_slope: must be > 0, got -0.5",
    );
}

#[test]
fn malformed_curve_strings_are_rejected() {
    rejects(
        "[power]\ncore_vf = 400\n",
        "power.core_vf: expected a string of mhz:volts points",
    );
    rejects(
        "[power]\ncore_vf = \"400-0.9\"\n",
        "power.core_vf: expected `mhz:volts`, got `400-0.9`",
    );
    rejects("[power]\ncore_vf = \"x:0.9\"\n", "power.core_vf: bad frequency `x`");
    rejects(
        "[power]\nmem_vf = \"400:0.9 0.95\"\n",
        "power.mem_vf: bad voltage `0.9 0.95`",
    );
    rejects(
        "[power]\ncore_vf = \" , \"\n",
        "power.core_vf: curve needs at least one (mhz, volts) point",
    );
}

#[test]
fn curve_validation_errors_surface_through_the_key() {
    // The shared `VfCurve::try_from_points` diagnoses flow through
    // prefixed with the offending key.
    rejects(
        "[power]\ncore_vf = \"inf:1\"\n",
        "power.core_vf: point 0 (inf:1) must be finite",
    );
    rejects(
        "[power]\ncore_vf = \"400:-0.85\"\n",
        "power.core_vf: point 0 (400:-0.85) must be positive",
    );
    rejects(
        "[power]\nmem_vf = \"400:0.85, 400:0.9\"\n",
        "power.mem_vf: duplicate frequency 400 MHz at point 1",
    );
    rejects(
        "[power]\ncore_vf = \"600:0.95, 400:0.85\"\n",
        "power.core_vf: frequencies must be strictly ascending: point 1 (400 MHz) after 600 MHz",
    );
}

#[test]
fn partial_power_sections_inherit_gtx980_defaults() {
    // A config naming only one knob keeps the builtin calibration for
    // everything else — sparse overrides are the common on-disk shape.
    let cfg = from_text("[power.leakage]\nleak_w = 21.5\n").unwrap();
    let d = PowerModel::gtx980();
    assert_eq!(cfg.power.leakage.leak_w, 21.5);
    assert_eq!(cfg.power.leakage.static_w, d.leakage.static_w);
    assert_eq!(cfg.power.dynamic, d.dynamic);
    assert_eq!(cfg.power.core_curve, d.core_curve);
    // And the sparse form still round-trips (to_text emits the full
    // resolved model, which re-parses to the same Config).
    let again = from_text(&to_text(&cfg)).unwrap();
    assert_eq!(again, cfg);
}
