//! Property-based validation of model invariants (proptest substitute:
//! `gpufreq::util::prop`, see DESIGN.md "Offline substitutions"), plus a
//! randomized PJRT-vs-native equivalence sweep.

use gpufreq::model::{self, HwParams, KernelCounters};
use gpufreq::runtime::Runtime;
use gpufreq::util::prop::{forall, Rng};

fn random_counters(r: &mut Rng) -> KernelCounters {
    let gld_body = r.range(1.0, 32.0).round();
    let wpb = r.u32(1, 16) as f64;
    let blocks_per_sm = r.u32(1, 8) as f64;
    KernelCounters {
        l2_hr: r.range(0.0, 1.0),
        gld_trans: gld_body + r.range(0.0, 4.0),
        avr_inst: r.range(0.1, 200.0),
        n_blocks: r.u32(16, 1024) as f64,
        wpb,
        aw: wpb * blocks_per_sm,
        n_sm: r.u32(1, 16) as f64,
        o_itrs: r.u32(1, 256) as f64,
        i_itrs: r.u32(0, 64) as f64,
        uses_smem: r.chance(0.5),
        smem_conflict: r.range(1.0, 8.0),
        gld_body,
        gld_edge: r.range(0.0, 16.0).round(),
        mem_ops: r.u32(1, 6) as f64,
        l1_hr: 0.0,
    }
}

fn random_clock(r: &mut Rng) -> f64 {
    (r.u32(4, 10) * 100) as f64
}

#[test]
fn prop_predictions_positive_and_finite() {
    forall(
        101,
        500,
        |r| (random_counters(r), random_clock(r), random_clock(r)),
        |(c, cf, mf)| {
            let hw = HwParams::paper_defaults();
            let p = model::predict(c, &hw, *cf, *mf);
            p.t_active > 0.0
                && p.t_active.is_finite()
                && p.t_exec_cycles >= p.t_active * 0.999
                && p.time_us > 0.0
        },
    );
}

#[test]
fn prop_time_equals_cycles_over_frequency() {
    forall(
        102,
        300,
        |r| (random_counters(r), random_clock(r), random_clock(r)),
        |(c, cf, mf)| {
            let p = model::predict(c, &HwParams::paper_defaults(), *cf, *mf);
            (p.time_us - p.t_exec_cycles / cf).abs() < 1e-9 * p.time_us.max(1.0)
        },
    );
}

#[test]
fn prop_mem_frequency_monotone_within_regime() {
    // Raising the memory clock never slows a kernel as long as the
    // regime does not flip (boundary jumps analysed in DESIGN.md).
    forall(
        103,
        300,
        |r| (random_counters(r), random_clock(r)),
        |(c, cf)| {
            let hw = HwParams::paper_defaults();
            let lo = model::predict(c, &hw, *cf, 400.0);
            let hi = model::predict(c, &hw, *cf, 1000.0);
            lo.regime != hi.regime || hi.time_us <= lo.time_us * 1.0001
        },
    );
}

#[test]
fn prop_core_frequency_speeds_up_compute_bound() {
    forall(
        104,
        200,
        |r| {
            let mut c = random_counters(r);
            c.uses_smem = false;
            c.l2_hr = 0.95;
            c.avr_inst = r.range(50.0, 500.0);
            c
        },
        |c| {
            let hw = HwParams::paper_defaults();
            let slow = model::predict(c, &hw, 400.0, 700.0);
            let fast = model::predict(c, &hw, 1000.0, 700.0);
            // Compute-dominated kernels scale ~inverse with core clock.
            let speedup = slow.time_us / fast.time_us;
            speedup > 2.0
        },
    );
}

#[test]
fn prop_rounds_scale_with_grid() {
    // Doubling the grid (blocks) doubles T_exec once past one full wave.
    forall(
        105,
        200,
        |r| (random_counters(r), random_clock(r), random_clock(r)),
        |(c, cf, mf)| {
            let hw = HwParams::paper_defaults();
            let full_wave = c.wpb * c.n_blocks >= c.aw * c.n_sm;
            if !full_wave {
                return true;
            }
            let p1 = model::predict(c, &hw, *cf, *mf);
            let mut c2 = *c;
            c2.n_blocks *= 2.0;
            let p2 = model::predict(&c2, &hw, *cf, *mf);
            (p2.t_exec_cycles / p1.t_exec_cycles - 2.0).abs() < 1e-6
        },
    );
}

#[test]
fn prop_l2_hit_rate_reduces_memory_time() {
    forall(
        106,
        200,
        |r| {
            let mut c = random_counters(r);
            c.uses_smem = false;
            c.avr_inst = 0.2; // memory-bound
            c.aw = 64.0;
            c
        },
        |c| {
            let hw = HwParams::paper_defaults();
            let mut hot = *c;
            hot.l2_hr = (c.l2_hr + 0.4).min(1.0);
            let cold = model::predict(c, &hw, 700.0, 700.0);
            let warm = model::predict(&hot, &hw, 700.0, 700.0);
            // Monotone within a regime; boundary jumps are a documented
            // property of the piecewise model (DESIGN.md).
            cold.regime != warm.regime || warm.time_us <= cold.time_us * 1.0001
        },
    );
}

#[test]
fn prop_pjrt_matches_native_on_random_inputs() {
    // 256 random (counters, frequency) rows through the PJRT executor
    // (emulated: same f32 feature packing and computation the AOT
    // artifact lowers) must agree with the scalar Rust model to f32
    // tolerance.
    let rt = Runtime::emulated();
    let hw = HwParams::paper_defaults();
    let mut rng = Rng::new(107);
    let cases: Vec<(KernelCounters, f64, f64)> = (0..256)
        .map(|_| (random_counters(&mut rng), random_clock(&mut rng), random_clock(&mut rng)))
        .collect();
    let rows: Vec<_> = cases.iter().map(|(c, cf, mf)| c.to_features(*cf, *mf)).collect();
    let got = rt.predict(&rows, &hw.to_f32()).unwrap();
    for ((c, cf, mf), g) in cases.iter().zip(got) {
        let native = model::predict(c, &hw, *cf, *mf);
        let rel = (g[2] as f64 - native.time_us).abs() / native.time_us.max(1e-9);
        assert!(
            rel < 5e-4,
            "pjrt {} vs native {} for {c:?} at ({cf},{mf})",
            g[2],
            native.time_us
        );
        assert_eq!(g[3] as u32, native.regime as u32, "{c:?} ({cf},{mf})");
    }
}

#[test]
fn prop_simulator_deterministic_across_runs() {
    use gpufreq::sim::engine::simulate;
    use gpufreq::sim::{Clocks, GpuSpec};
    let spec = GpuSpec::default();
    forall(
        108,
        8,
        |r| (r.u32(0, 11), random_clock(r), random_clock(r)),
        |(idx, cf, mf)| {
            let k = &gpufreq::kernels::all()[*idx as usize];
            let a = simulate(&spec, Clocks::new(*cf, *mf), k);
            let b = simulate(&spec, Clocks::new(*cf, *mf), k);
            a.stats.elapsed_ns == b.stats.elapsed_ns && a.stats.l2_hits == b.stats.l2_hits
        },
    );
}
