//! Property tests (util::prop) for the v2 wire protocol's JSON
//! round-tripping: every v2 request/response shape must survive
//! encode → parse → encode **byte-identically** — including string
//! escapes and adversarial float values — and non-finite floats must
//! never reach the wire as unparseable bytes (they render as `null`).
//!
//! The first `render` is the canonical form (Rust's shortest-roundtrip
//! f64 formatting), so byte-identity of the second render proves the
//! parser loses nothing the renderer can express.
//!
//! The file also pins the parse layer's deadline validation: malformed
//! `deadline_us` values on `/v2/plan` and `/v2/jobs` are structured
//! 400s before any solver or scheduler work happens.

use gpufreq::service::json::Value;
use gpufreq::util::prop::{forall, Rng};

/// A finite f64 drawn from several magnitudes (integers, tiny,
/// huge, negative) — everything a counters/hw/latency field can hold.
fn finite_f64(r: &mut Rng) -> f64 {
    match r.u32(0, 5) {
        0 => r.u32(0, 2000) as f64,                 // MHz-like integers
        1 => r.range(0.0, 1.0),                     // hit rates
        2 => -r.range(0.0, 1e6),                    // negatives
        3 => r.range(0.0, 1e-9),                    // denormal-ish tiny
        4 => r.range(1e12, 1e15),                   // huge cycle counts
        _ => r.range(0.0, 1e6),
    }
}

/// Strings exercising every escape class the renderer knows: quotes,
/// backslashes, control characters, multi-byte UTF-8.
fn wire_string(r: &mut Rng) -> String {
    const POOL: &[&str] = &[
        "a", "Z", "7", "_", "-", " ", "\"", "\\", "\n", "\r", "\t", "\u{1}", "\u{1f}", "/",
        "é", "λ", "😀", "dev-", "krn-", "{", "}", "[", "]",
    ];
    let n = r.u32(0, 12);
    let mut s = String::new();
    for _ in 0..n {
        s.push_str(POOL[r.u32(0, POOL.len() as u32 - 1) as usize]);
    }
    s
}

fn obj(fields: Vec<(String, Value)>) -> Value {
    Value::Obj(fields)
}

fn key(r: &mut Rng, canonical: &str) -> String {
    // Mostly the real field name; sometimes an adversarial one, since
    // unknown fields must round-trip too (clients may send extras).
    if r.chance(0.85) {
        canonical.to_string()
    } else {
        wire_string(r)
    }
}

fn counters_value(r: &mut Rng) -> Value {
    let fields = [
        "l2_hr", "gld_trans", "avr_inst", "n_blocks", "wpb", "aw", "n_sm", "o_itrs", "i_itrs",
        "smem_conflict", "gld_body", "gld_edge", "mem_ops", "l1_hr",
    ];
    let mut out: Vec<(String, Value)> = fields
        .iter()
        .map(|f| (key(r, f), Value::num(finite_f64(r))))
        .collect();
    out.push(("uses_smem".to_string(), Value::Bool(r.chance(0.5))));
    obj(out)
}

fn hw_value(r: &mut Rng) -> Value {
    let fields = ["dm_lat_a", "dm_lat_b", "dm_del", "l2_lat", "l2_del", "sh_lat", "inst_cycle"];
    obj(fields.iter().map(|f| (key(r, f), Value::num(finite_f64(r)))).collect())
}

fn vf_value(r: &mut Rng) -> Value {
    let n = r.u32(1, 4);
    Value::arr(
        (0..n)
            .map(|_| Value::arr(vec![Value::num(finite_f64(r)), Value::num(finite_f64(r))]))
            .collect(),
    )
}

/// `POST /v2/devices` request.
fn device_request(r: &mut Rng) -> Value {
    let mut fields = vec![("name".to_string(), Value::str(wire_string(r)))];
    if r.chance(0.7) {
        fields.push(("hw".to_string(), hw_value(r)));
    }
    if r.chance(0.5) {
        fields.push((
            "power".to_string(),
            obj(vec![
                ("core_coeff".to_string(), Value::num(finite_f64(r))),
                ("mem_coeff".to_string(), Value::num(finite_f64(r))),
                ("static_w".to_string(), Value::num(finite_f64(r))),
                ("leak_w".to_string(), Value::num(finite_f64(r))),
                ("leak_v_ref".to_string(), Value::num(finite_f64(r))),
                ("leak_v_slope".to_string(), Value::num(finite_f64(r))),
                ("core_vf".to_string(), vf_value(r)),
                ("mem_vf".to_string(), vf_value(r)),
            ]),
        ));
    }
    obj(fields)
}

/// `POST /v2/kernels` request.
fn kernel_request(r: &mut Rng) -> Value {
    obj(vec![
        ("name".to_string(), Value::str(wire_string(r))),
        ("counters".to_string(), counters_value(r)),
    ])
}

fn handle_pair(r: &mut Rng) -> [(String, Value); 2] {
    [
        ("device".to_string(), Value::str(format!("dev-{}", r.u32(1, 9)))),
        ("kernel".to_string(), Value::str(format!("krn-{}", r.u32(1, 9)))),
    ]
}

/// `POST /v2/predict` request (batch-first).
fn predict_request(r: &mut Rng) -> Value {
    let n = r.u32(1, 8);
    let items: Vec<Value> = (0..n)
        .map(|_| {
            let mut fields = handle_pair(r).to_vec();
            fields.push(("core_mhz".to_string(), Value::num(finite_f64(r))));
            fields.push(("mem_mhz".to_string(), Value::num(finite_f64(r))));
            obj(fields)
        })
        .collect();
    obj(vec![
        ("requests".to_string(), Value::arr(items)),
        ("count".to_string(), Value::num(n as f64)),
    ])
}

fn estimate_value(r: &mut Rng) -> Value {
    let mut fields = handle_pair(r).to_vec();
    for f in ["core_mhz", "mem_mhz", "time_us", "t_active", "t_exec_cycles"] {
        fields.push((f.to_string(), Value::num(finite_f64(r))));
    }
    fields.push((
        "regime".to_string(),
        if r.chance(0.2) { Value::Null } else { Value::str(wire_string(r)) },
    ));
    obj(fields)
}

/// `POST /v2/predict` response.
fn predict_response(r: &mut Rng) -> Value {
    let n = r.u32(1, 6);
    obj(vec![
        ("results".to_string(), Value::arr((0..n).map(|_| estimate_value(r)).collect())),
        ("count".to_string(), Value::num(n as f64)),
    ])
}

fn config_point_value(r: &mut Rng) -> Value {
    obj([
        "core_mhz", "mem_mhz", "time_us", "power_w", "power_dynamic_w", "power_leakage_w",
        "energy_mj", "edp",
    ]
    .iter()
    .map(|f| (f.to_string(), Value::num(finite_f64(r))))
    .collect())
}

/// `POST /v2/advise` response.
fn advise_response(r: &mut Rng) -> Value {
    let mut fields = handle_pair(r).to_vec();
    fields.push(("objective".to_string(), Value::str(wire_string(r))));
    fields.push(("feasible".to_string(), Value::Bool(r.chance(0.5))));
    fields.push(("best".to_string(), config_point_value(r)));
    fields.push(("fastest".to_string(), config_point_value(r)));
    fields.push(("points_evaluated".to_string(), Value::num(r.u32(1, 49) as f64)));
    if r.chance(0.5) {
        fields.push(("deadline_us".to_string(), Value::num(finite_f64(r))));
    }
    if r.chance(0.3) {
        let n = r.u32(1, 5);
        fields.push((
            "points".to_string(),
            Value::arr((0..n).map(|_| config_point_value(r)).collect()),
        ));
    }
    obj(fields)
}

/// `POST /v2/jobs` request (the streaming scheduler's submit shape).
fn jobs_request(r: &mut Rng) -> Value {
    let mut fields = vec![("kernel".to_string(), Value::str(format!("krn-{}", r.u32(1, 9))))];
    if r.chance(0.7) {
        fields.push(("scale".to_string(), Value::num(finite_f64(r))));
    }
    if r.chance(0.6) {
        fields.push(("deadline_us".to_string(), Value::num(finite_f64(r))));
    }
    if r.chance(0.7) {
        fields.push((key(r, "name"), Value::str(wire_string(r))));
    }
    obj(fields)
}

/// `GET /v2/jobs/{id}` response (one job record on the wire).
fn job_response(r: &mut Rng) -> Value {
    let id = r.u32(1, 99);
    let mut fields = vec![
        ("id".to_string(), Value::str(format!("job-{id}"))),
        ("name".to_string(), Value::str(wire_string(r))),
        ("kernel".to_string(), Value::str(format!("krn-{}", r.u32(1, 9)))),
        ("scale".to_string(), Value::num(finite_f64(r))),
        (
            "state".to_string(),
            Value::str(
                ["queued", "scheduled", "running", "done", "missed", "cancelled"]
                    [r.u32(0, 5) as usize],
            ),
        ),
        ("submitted_at_us".to_string(), Value::num(finite_f64(r))),
    ];
    for opt in ["deadline_at_us", "predicted_us", "started_at_us", "finished_at_us"] {
        if r.chance(0.5) {
            fields.push((opt.to_string(), Value::num(finite_f64(r))));
        }
    }
    if r.chance(0.5) {
        fields.push(("device".to_string(), Value::str(format!("dev-{}", r.u32(1, 9)))));
        fields.push(("plan_id".to_string(), Value::str(format!("plan-{}", r.u32(1, 999)))));
    }
    if r.chance(0.3) {
        fields.push(("cause".to_string(), Value::str(wire_string(r))));
    }
    obj(fields)
}

/// Devices/kernels list responses.
fn list_response(r: &mut Rng) -> Value {
    let n = r.u32(0, 4);
    let devices: Vec<Value> = (0..n)
        .map(|i| {
            obj(vec![
                ("device".to_string(), Value::str(format!("dev-{}", i + 1))),
                ("name".to_string(), Value::str(wire_string(r))),
                ("hw".to_string(), hw_value(r)),
            ])
        })
        .collect();
    obj(vec![
        ("devices".to_string(), Value::arr(devices)),
        ("count".to_string(), Value::num(n as f64)),
    ])
}

/// encode → parse → encode must be byte-identical, and the parsed tree
/// must equal the original.
fn round_trips(v: &Value) -> bool {
    let first = v.render();
    let Ok(parsed) = Value::parse(&first) else {
        return false;
    };
    parsed == *v && parsed.render() == first
}

#[test]
fn device_requests_round_trip_byte_identically() {
    forall(0xD0, 300, device_request, round_trips);
}

#[test]
fn kernel_requests_round_trip_byte_identically() {
    forall(0xC1, 300, kernel_request, round_trips);
}

#[test]
fn predict_requests_round_trip_byte_identically() {
    forall(0x9E, 300, predict_request, round_trips);
}

#[test]
fn predict_responses_round_trip_byte_identically() {
    forall(0x9F, 300, predict_response, round_trips);
}

#[test]
fn advise_responses_round_trip_byte_identically() {
    forall(0xA0, 200, advise_response, round_trips);
}

#[test]
fn list_responses_round_trip_byte_identically() {
    forall(0xA1, 200, list_response, round_trips);
}

#[test]
fn jobs_requests_and_responses_round_trip_byte_identically() {
    forall(0x10B, 300, jobs_request, round_trips);
    forall(0x10C, 300, job_response, round_trips);
}

/// Parse-layer deadline validation: a malformed `deadline_us` on
/// `POST /v2/plan` or `POST /v2/jobs` is a structured 400 **before**
/// the solver or the scheduler sees the request — zero/negative
/// values, strings, arrays, and `null` (the wire form of a non-finite
/// float, per `non_finite_floats_never_reach_the_wire`) all refuse
/// identically, and nothing is admitted.
#[test]
fn bad_deadlines_are_rejected_at_the_parse_layer() {
    use gpufreq::dvfs::PowerModel;
    use gpufreq::engine::Engine;
    use gpufreq::microbench;
    use gpufreq::model::{HwParams, KernelCounters};
    use gpufreq::service::{Client, Service, ServiceConfig, ServiceState};

    let hw = HwParams::paper_defaults();
    let mut state =
        ServiceState::new(Engine::native(hw), PowerModel::gtx980(), microbench::standard_grid());
    state.register_kernel(
        "VA",
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        },
    );
    let svc = Service::start(state, ServiceConfig::default()).expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();

    for bad in ["0", "-1", "-2.5e8", "null", "\"soon\"", "[1e6]", "{}"] {
        for path in ["/v2/plan", "/v2/jobs"] {
            let body = if path == "/v2/plan" {
                format!(r#"{{"jobs":[{{"kernel":"VA","deadline_us":{bad}}}]}}"#)
            } else {
                format!(r#"{{"kernel":"VA","deadline_us":{bad}}}"#)
            };
            let r = c.post(path, &body).unwrap();
            assert_eq!(r.status, 400, "{path} deadline_us={bad}: {}", r.body);
            let v = r.json().unwrap();
            assert_eq!(
                v.get("code").and_then(Value::as_str),
                Some("bad_request"),
                "{path} deadline_us={bad}: {}",
                r.body
            );
            assert!(r.body.contains("deadline_us"), "{path} deadline_us={bad}: {}", r.body);
        }
    }
    // Nothing reached the scheduler or the solver.
    let r = c.get("/v2/jobs").unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(0.0), "{}", r.body);
    let stats = v.get("stats").expect("stats block");
    assert_eq!(stats.get("submitted").and_then(Value::as_f64), Some(0.0));
    let m = c.get("/metrics").unwrap();
    assert!(m.body.contains("scheduler_jobs_submitted_total 0"), "{}", m.body);

    drop(c);
    svc.shutdown();
}

#[test]
fn non_finite_floats_never_reach_the_wire() {
    // Inject a non-finite number somewhere in an otherwise-valid
    // response: the rendered document must still parse (the value
    // degrades to `null`), and the bytes must not contain inf/NaN.
    forall(
        0xBAD,
        300,
        |r| {
            let poison = match r.u32(0, 2) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                _ => f64::NEG_INFINITY,
            };
            let mut resp = predict_response(r);
            // Overwrite one numeric leaf with the poison value.
            if let Value::Obj(fields) = &mut resp {
                if let Some((_, Value::Arr(results))) =
                    fields.iter_mut().find(|(k, _)| k.as_str() == "results")
                {
                    if let Some(Value::Obj(est)) = results.first_mut() {
                        if let Some((_, slot)) =
                            est.iter_mut().find(|(k, _)| k.as_str() == "time_us")
                        {
                            *slot = Value::num(poison);
                        }
                    }
                }
            }
            resp
        },
        |resp| {
            let text = resp.render();
            if text.contains("inf") || text.contains("NaN") {
                return false;
            }
            let Ok(parsed) = Value::parse(&text) else {
                return false;
            };
            // Re-rendering the parsed (nulled) tree is stable.
            parsed.render() == text
        },
    );
}
