//! Cross-module integration tests: microbench → profiler → model →
//! runtime → coordinator, end to end on reduced grids.

use std::time::Duration;

use gpufreq::baselines::{standard_baselines, ConstLatency, PaperModel};
use gpufreq::engine::BatchServer;
use gpufreq::coordinator::sweep::run_sweep;
use gpufreq::coordinator::validate::{validate_with, ground_truth_us};
use gpufreq::dvfs::{advise, Objective, PowerModel};
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::model::HwParams;
use gpufreq::profiler;
use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};

fn reduced_grid() -> Vec<(f64, f64)> {
    let steps = [400.0, 700.0, 1000.0];
    steps.iter().flat_map(|&c| steps.iter().map(move |&m| (c, m))).collect()
}

#[test]
fn extraction_recovers_calibrated_hardware() {
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    assert!((ex.hw.dm_lat_a - spec.dm_access_mem_cycles).abs() < 8.0);
    assert!((ex.hw.dm_lat_b - spec.dm_path_core_cycles).abs() < 8.0);
    assert!(ex.dm_lat_fit.r_squared > 0.99);
    assert!(ex.hw.dm_del > spec.dm_burst_mem_cycles);
    assert!(ex.bandwidth_at_baseline.efficiency > 0.7);
    assert!(ex.bandwidth_at_baseline.efficiency < 0.95);
}

#[test]
fn native_validation_meets_paper_band_on_reduced_grid() {
    // The full-grid headline lives in the full_sweep example and the
    // fig14 bench; here a 3x3 grid keeps test time low while still
    // covering the frequency extremes.
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    let model = PaperModel { hw: ex.hw };
    let v = validate_with(&spec, &kernels::all(), &model, &reduced_grid());
    let mape = v.overall_mape();
    assert!(mape < 0.06, "overall MAPE {:.1}% (paper: 3.5%)", mape * 100.0);
    for k in &v.per_kernel {
        assert!(k.mape() < 0.12, "{}: {:.1}%", k.kernel, k.mape() * 100.0);
    }
}

#[test]
fn paper_model_beats_baselines() {
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    let ks = [kernels::vector_add(), kernels::matrix_mul_shared(), kernels::black_scholes()];
    let rows = tables::run_ablation(&spec, &ks, ex.hw, standard_baselines(ex.hw), &reduced_grid());
    let paper = rows.iter().find(|(n, _, _)| n == "paper").unwrap().1;
    let const_lat = rows.iter().find(|(n, _, _)| n == "const-latency").unwrap().1;
    let linear = rows.iter().find(|(n, _, _)| n == "linear-freq").unwrap().1;
    assert!(paper < const_lat, "paper {paper} vs const-latency {const_lat}");
    assert!(paper < linear, "paper {paper} vs linear {linear}");
}

#[test]
fn const_latency_fails_hard_on_memory_scaling() {
    // The motivating claim: frequency-unaware models blow up when the
    // memory clock moves. VA at (700, 400).
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    let k = kernels::vector_add();
    let p = profiler::profile_at(&spec, &k, Clocks::new(700.0, 700.0));
    let cl = ConstLatency { hw: ex.hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 };
    let truth_slow = ground_truth_us(&spec, &k, Clocks::new(700.0, 400.0));
    let pred = gpufreq::baselines::Predictor::predict_us(&cl, &p.counters, 700.0, 400.0);
    let err = (pred - truth_slow).abs() / truth_slow;
    assert!(err > 0.25, "const-latency should be badly wrong here, err {err:.2}");
}

#[test]
fn pjrt_grid_predictions_match_native_model() {
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let hw = HwParams::paper_defaults();
    // Two sharded drain workers over the always-available emulated
    // executor; the artifact-pinned `start_default` path is covered by
    // the feature-gated runtime tests.
    let (server, _h) = BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(1), 2)
        .expect("emulated executor always starts");
    for k in [kernels::vector_add(), kernels::matrix_mul_shared()] {
        let p = profiler::profile_at(&spec, &k, baseline);
        let grid = reduced_grid();
        let preds = server.predict_grid(&p.counters, &grid).unwrap();
        for (pred, &(cf, mf)) in preds.iter().zip(&grid) {
            let native = gpufreq::model::predict(&p.counters, &hw, cf, mf);
            let rel = (pred.time_us - native.time_us).abs() / native.time_us;
            assert!(rel < 1e-4, "{} ({cf},{mf}): {} vs {}", k.name, pred.time_us, native.time_us);
            assert_eq!(pred.regime.map(|r| r as u32), Some(native.regime as u32));
        }
    }
}

#[test]
fn engine_facade_serves_every_legacy_consumer_path() {
    // One engine, four consumers: validation, the advisor, the
    // predicted sweep and the ablation adapter all agree with the
    // direct model calls, and repeats ride the shared cache.
    use gpufreq::coordinator::sweep::predicted_sweep;
    use gpufreq::coordinator::validate::validate_with_engine;
    use gpufreq::dvfs::advise_with_engine;
    use gpufreq::engine::Engine;

    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let ex = microbench::extract(&spec, baseline);
    let engine = Engine::native(ex.hw);
    let ks = [kernels::vector_add(), kernels::black_scholes()];
    let grid = reduced_grid();

    // Validation through the engine == validation through the predictor.
    let v_engine = validate_with_engine(&spec, &ks, &engine, &grid).unwrap();
    let v_direct = validate_with(&spec, &ks, &PaperModel { hw: ex.hw }, &grid);
    for (a, b) in v_engine.per_kernel.iter().zip(&v_direct.per_kernel) {
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.pred_us.to_bits(), pb.pred_us.to_bits());
        }
    }

    // Advisor through the engine: grid now cached, zero recomputes.
    let hits_before = engine.cache_stats().hits;
    let power = PowerModel::gtx980();
    for k in &ks {
        let p = profiler::profile_at(&spec, k, baseline);
        let (best, points) =
            advise_with_engine(&p.counters, &engine, &power, &grid, Objective::Energy).unwrap();
        assert_eq!(points.len(), grid.len());
        assert!(best.energy_mj > 0.0);
    }
    assert!(
        engine.cache_stats().hits >= hits_before + 2 * grid.len() as u64,
        "advisor re-queries must be cache hits"
    );

    // Predicted sweep through the engine matches scalar predictions.
    let profiles: Vec<_> = ks.iter().map(|k| profiler::profile_at(&spec, k, baseline)).collect();
    let ps = predicted_sweep(&engine, &profiles, &grid).unwrap();
    assert_eq!(ps.points.len(), ks.len() * grid.len());
    for pt in &ps.points {
        let prof = profiles.iter().find(|p| p.kernel == pt.kernel).unwrap();
        let want = gpufreq::model::predict(&prof.counters, &ex.hw, pt.core_mhz, pt.mem_mhz);
        assert_eq!(pt.time_us.to_bits(), want.time_us.to_bits());
    }
}

#[test]
fn sweep_speedups_reproduce_fig2_shape() {
    // Fig. 2 qualitative claims: TR/BS/VA/convSp speed up ~2.5x with
    // memory frequency at core=1000; MMG/MMS barely move; at mem=1000
    // MMG/MMS speed up strongly with core frequency.
    let spec = GpuSpec::default();
    let ks = kernels::fig2_set();
    let pairs = vec![(1000.0, 400.0), (1000.0, 1000.0), (400.0, 1000.0)];
    let sweep = run_sweep(&spec, &ks, &pairs, 4);
    for name in ["TR", "BS", "VA", "convSp"] {
        let sp = sweep.speedup(name, (1000.0, 400.0), (1000.0, 1000.0)).unwrap();
        assert!(sp > 1.9, "{name} memory speedup {sp:.2}");
    }
    for name in ["MMG", "MMS"] {
        let sp = sweep.speedup(name, (1000.0, 400.0), (1000.0, 1000.0)).unwrap();
        assert!(sp < 1.6, "{name} memory speedup {sp:.2}");
        let core_sp = sweep.speedup(name, (400.0, 1000.0), (1000.0, 1000.0)).unwrap();
        assert!(core_sp > 1.7, "{name} core speedup {core_sp:.2}");
    }
}

#[test]
fn advisor_saves_energy_against_max_frequency() {
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let ex = microbench::extract(&spec, baseline);
    let model = PaperModel { hw: ex.hw };
    let power = PowerModel::gtx980();
    let grid = microbench::standard_grid();
    for k in kernels::all() {
        let p = profiler::profile_at(&spec, &k, baseline);
        let (best, points) = advise(&p.counters, &model, &power, &grid, Objective::Energy);
        let max_freq =
            points.iter().find(|c| c.core_mhz == 1000.0 && c.mem_mhz == 1000.0).unwrap();
        assert!(
            best.energy_mj <= max_freq.energy_mj,
            "{}: advisor must never be worse than flat-out",
            k.name
        );
    }
}

#[test]
fn l1_future_work_extension_repairs_tex_error() {
    // The paper's §VII: "our model ... does not take texture/L1 cache
    // into account, which may introduce larger error for kernels
    // containing access requests to them." We implement both halves:
    // the TEX kernel exposes the error, the L1-extended model repairs
    // it — and reduces exactly to the published model at l1_hr = 0.
    use gpufreq::baselines::L1Extended;
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let ex = microbench::extract(&spec, baseline);
    let l1_lat = microbench::l1_latency_probe(&spec, baseline);
    let k = kernels::texture_filter();
    let p = profiler::profile_at(&spec, &k, baseline);
    assert!(p.counters.l1_hr > 0.4, "TEX should be L1-absorbed, l1_hr {}", p.counters.l1_hr);

    let paper = PaperModel { hw: ex.hw };
    let extended = L1Extended::new(ex.hw, l1_lat);
    let grid = reduced_grid();
    let v_paper =
        gpufreq::coordinator::validate::validate_kernel_with(&spec, &k, &p, &paper, &grid);
    let v_ext =
        gpufreq::coordinator::validate::validate_kernel_with(&spec, &k, &p, &extended, &grid);
    assert!(
        v_ext.mape() < v_paper.mape(),
        "extension must help: paper {:.1}% vs +l1 {:.1}%",
        v_paper.mape() * 100.0,
        v_ext.mape() * 100.0
    );
    assert!(v_ext.mape() < 0.12, "+l1 MAPE {:.1}%", v_ext.mape() * 100.0);

    // Strict-extension property: identical on an L1-free kernel.
    let va = kernels::vector_add();
    let pva = profiler::profile_at(&spec, &va, baseline);
    for &(cf, mf) in &grid {
        let a = gpufreq::baselines::Predictor::predict_us(&paper, &pva.counters, cf, mf);
        let b = gpufreq::baselines::Predictor::predict_us(&extended, &pva.counters, cf, mf);
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn report_emitters_do_not_panic_and_carry_data() {
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let (t2, note) = tables::table2(&spec);
    assert_eq!(t2.rows.len(), 7);
    assert!(note.contains("Eq. (4) fit"));
    let t3 = tables::table3(&spec);
    assert_eq!(t3.rows.len(), 7);
    let (a, b) = tables::fig5(&spec, baseline, 512);
    assert!(!a.rows.is_empty() && !b.rows.is_empty());
    // CSV and ASCII render for each.
    for t in [&t2, &t3, &a, &b] {
        assert!(!t.csv().is_empty());
        assert!(!t.ascii().is_empty());
    }
}

#[test]
fn methodology_generalizes_to_second_gpu() {
    // configs/gtx960.toml describes a different Maxwell part (8 SMs,
    // 1 MiB L2, slower channels). The workflow — microbench once,
    // profile once, predict everywhere — must hold there with NO model
    // re-tuning: all parameters come from the probes.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/gtx960.toml");
    let cfg = gpufreq::config::load(&path).unwrap();
    assert_eq!(cfg.gpu.n_sm, 8);
    let ex = microbench::extract(&cfg.gpu, cfg.sweep.baseline());
    // The probes must recover the *different* calibration of this part.
    assert!((ex.hw.dm_lat_a - 240.0).abs() < 10.0, "slope {}", ex.hw.dm_lat_a);
    assert!((ex.hw.dm_lat_b - 300.0).abs() < 10.0, "intercept {}", ex.hw.dm_lat_b);
    let model = PaperModel { hw: ex.hw };
    let ks = [
        kernels::vector_add(),
        kernels::black_scholes(),
        kernels::matrix_mul_shared(),
        kernels::fast_walsh(),
    ];
    let v = validate_with(&cfg.gpu, &ks, &model, &reduced_grid());
    assert!(
        v.overall_mape() < 0.08,
        "GTX 960-class MAPE {:.1}%",
        v.overall_mape() * 100.0
    );
}

#[test]
fn cli_parse_and_report_pipeline() {
    use gpufreq::cli;
    let args = cli::parse_args(&["report".into(), "table1".into()]).unwrap();
    assert_eq!(cli::run(args).unwrap(), 0);
    let args = cli::parse_args(&["list-kernels".into()]).unwrap();
    assert_eq!(cli::run(args).unwrap(), 0);
    let args = cli::parse_args(&["bogus-command".into()]).unwrap();
    assert_eq!(cli::run(args).unwrap(), 2);
}
