//! Regime-boundary property tests (`util::prop`, the proptest
//! substitute): the piecewise model must be *numerically tame* where
//! its pipeline cases switch.
//!
//! Analysis (DESIGN.md §8 "Boundary continuity"): with `mem_ops = 1`
//! every pairwise gap between the four global-memory regime expressions
//! at their switching condition is bounded by ~`T_active / #Aw`, i.e. a
//! relative discontinuity of at most `1/aw` (the Compute ↔
//! FewWarpsLongCompute boundary is exactly continuous). The generators
//! therefore sample high-occupancy kernels (`aw ≥ 128`), where an
//! epsilon frequency step across any boundary moves the prediction by
//! well under 1 %. The shared-memory regimes are excluded: the
//! SmemLight ↔ SmemIntense switch changes the pipeline *structure*
//! (queue-hidden vs three-phase) and the model is intentionally
//! discontinuous there (jumps up to ~90 % — measured and documented in
//! DESIGN.md), exactly like the paper's own Figs. 10/11 case split.

use gpufreq::model::{self, HwParams, KernelCounters, Regime};
use gpufreq::util::prop::{forall, Rng};

fn base_counters() -> KernelCounters {
    KernelCounters {
        l2_hr: 0.0,
        gld_trans: 1.0,
        avr_inst: 1.0,
        n_blocks: 4096.0,
        wpb: 8.0,
        aw: 128.0,
        n_sm: 16.0,
        o_itrs: 64.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 1.0,
        gld_edge: 0.0,
        mem_ops: 1.0,
        l1_hr: 0.0,
    }
}

/// Log-uniform sample in [lo, hi].
fn log_range(r: &mut Rng, lo: f64, hi: f64) -> f64 {
    (r.range(lo.ln(), hi.ln())).exp()
}

/// High-occupancy global-memory kernels: mixed compute/memory balance,
/// mostly exercising the Compute ↔ Memory boundary.
fn gen_mixed(r: &mut Rng) -> KernelCounters {
    let wpb = if r.chance(0.5) { 8.0 } else { 16.0 };
    let blocks_per_sm = [16.0, 24.0, 32.0][r.u32(0, 2) as usize];
    KernelCounters {
        l2_hr: r.range(0.0, 0.95),
        gld_trans: r.range(1.0, 16.0),
        avr_inst: log_range(r, 0.1, 200.0),
        wpb,
        aw: wpb * blocks_per_sm,
        o_itrs: r.u32(32, 256) as f64,
        ..base_counters()
    }
}

/// Cache-hot, low-traffic kernels: pushes the few-warps regimes (1, 3)
/// and their boundaries with Compute/Memory.
fn gen_few_warps(r: &mut Rng) -> KernelCounters {
    KernelCounters {
        l2_hr: r.range(0.85, 0.995),
        gld_trans: r.range(1.0, 3.0),
        avr_inst: log_range(r, 0.05, 5.0),
        aw: [128.0, 192.0, 256.0][r.u32(0, 2) as usize],
        o_itrs: r.u32(64, 256) as f64,
        ..base_counters()
    }
}

fn random_mem_mhz(r: &mut Rng) -> f64 {
    [400.0, 550.0, 700.0, 850.0, 1000.0][r.u32(0, 4) as usize]
}

/// Scan the core-frequency axis in epsilon steps; at every regime
/// switch, check the relative jump, and everywhere check
/// finite/positive. Returns the transitions seen.
fn scan_boundaries(
    c: &KernelCounters,
    hw: &HwParams,
    mem_mhz: f64,
    step_mhz: f64,
    max_jump: f64,
) -> Vec<(Regime, Regime)> {
    let mut transitions = Vec::new();
    let mut prev: Option<(f64, Regime)> = None;
    let mut cf = 400.0;
    while cf <= 1000.0 + 1e-9 {
        let p = model::predict(c, hw, cf, mem_mhz);
        assert!(
            p.time_us.is_finite() && p.time_us > 0.0,
            "non-finite/non-positive at cf={cf} mf={mem_mhz}: {c:?}"
        );
        assert!(p.t_active.is_finite() && p.t_active > 0.0);
        if let Some((t_prev, r_prev)) = prev {
            if r_prev != p.regime {
                let jump = (p.time_us - t_prev).abs() / t_prev;
                assert!(
                    jump < max_jump,
                    "{:?} -> {:?} jump {:.3}% at cf={cf} mf={mem_mhz} (aw={}): {c:?}",
                    r_prev,
                    p.regime,
                    jump * 100.0,
                    c.aw
                );
                transitions.push((r_prev, p.regime));
            }
        }
        prev = Some((p.time_us, p.regime));
        cf += step_mhz;
    }
    transitions
}

#[test]
fn global_regime_boundaries_are_continuous_under_1pct() {
    let hw = HwParams::paper_defaults();
    let mut rng = Rng::new(2024);
    let mut n_boundaries = 0usize;
    let mut saw_compute_memory = false;
    for _ in 0..300 {
        let c = gen_mixed(&mut rng);
        let mf = random_mem_mhz(&mut rng);
        for (a, b) in scan_boundaries(&c, &hw, mf, 0.5, 0.01) {
            n_boundaries += 1;
            if (a == Regime::Compute && b == Regime::Memory)
                || (a == Regime::Memory && b == Regime::Compute)
            {
                saw_compute_memory = true;
            }
        }
    }
    assert!(n_boundaries > 10, "scan crossed only {n_boundaries} boundaries");
    assert!(saw_compute_memory, "Compute <-> Memory boundary never exercised");
}

#[test]
fn few_warps_boundaries_are_continuous_under_1pct() {
    let hw = HwParams::paper_defaults();
    let mut rng = Rng::new(4096);
    let mut n_boundaries = 0usize;
    let mut saw_few_warps = false;
    for _ in 0..400 {
        let c = gen_few_warps(&mut rng);
        let mf = random_mem_mhz(&mut rng);
        for (a, b) in scan_boundaries(&c, &hw, mf, 0.5, 0.01) {
            n_boundaries += 1;
            if matches!(a, Regime::FewWarpsLongCompute | Regime::FewWarpsShortCompute)
                || matches!(b, Regime::FewWarpsLongCompute | Regime::FewWarpsShortCompute)
            {
                saw_few_warps = true;
            }
        }
    }
    assert!(n_boundaries > 5, "scan crossed only {n_boundaries} boundaries");
    assert!(saw_few_warps, "few-warps boundaries never exercised");
}

#[test]
fn compute_to_few_warps_long_boundary_is_nearly_exact() {
    // Handpicked crossing of the Eq. (9) / Eq. (15) switch
    // (`comp_iter * (aw-1) = lat_iter`): with mem_ops = 1 the two
    // expressions coincide at the boundary, so the jump must be far
    // below the generic 1 % bound.
    let hw = HwParams::paper_defaults();
    let c = KernelCounters {
        l2_hr: 0.8,
        gld_trans: 1.0,
        avr_inst: 1.7,
        aw: 80.0,
        o_itrs: 64.0,
        ..base_counters()
    };
    let mut found = false;
    let mut prev: Option<(f64, Regime)> = None;
    let mut cf = 400.0;
    while cf <= 1000.0 + 1e-9 {
        let p = model::predict(&c, &hw, cf, 700.0);
        if let Some((t_prev, r_prev)) = prev {
            if r_prev != p.regime {
                let pair = (r_prev, p.regime);
                assert!(
                    pair == (Regime::Compute, Regime::FewWarpsLongCompute)
                        || pair == (Regime::FewWarpsLongCompute, Regime::Compute),
                    "unexpected transition {pair:?} at cf={cf}"
                );
                let jump = (p.time_us - t_prev).abs() / t_prev;
                assert!(jump < 0.005, "jump {:.4}% at cf={cf}", jump * 100.0);
                found = true;
            }
        }
        prev = Some((p.time_us, p.regime));
        cf += 0.25;
    }
    assert!(found, "the scan must cross the Compute/FewWarpsLongCompute boundary");
}

#[test]
fn compute_regime_time_monotone_in_inverse_core_frequency() {
    // Satellite property: within the Compute regime, time_us is
    // monotonically increasing in 1/core_mhz (equivalently, strictly
    // decreasing in core_mhz — higher clock never hurts compute-bound
    // kernels).
    let hw = HwParams::paper_defaults();
    forall(
        7001,
        200,
        |r| {
            let mut c = gen_mixed(r);
            c.avr_inst = log_range(r, 20.0, 500.0);
            c.l2_hr = r.range(0.5, 0.99);
            (c, random_mem_mhz(r))
        },
        |(c, mf)| {
            let mut last: Option<f64> = None;
            let mut cf = 400.0;
            while cf <= 1000.0 + 1e-9 {
                let p = model::predict(c, &hw, cf, *mf);
                if p.regime == Regime::Compute {
                    if let Some(prev_t) = last {
                        if p.time_us >= prev_t {
                            return false;
                        }
                    }
                    last = Some(p.time_us);
                } else {
                    last = None; // only compare within contiguous Compute spans
                }
                cf += 10.0;
            }
            true
        },
    );
}

#[test]
fn boundary_scan_also_holds_at_memory_axis() {
    // Same continuity property sweeping the *memory* clock with the
    // core clock fixed (the other epsilon direction over the grid).
    let hw = HwParams::paper_defaults();
    let mut rng = Rng::new(9090);
    let mut n_boundaries = 0usize;
    for _ in 0..200 {
        let c = gen_mixed(&mut rng);
        let cf = [400.0, 700.0, 1000.0][rng.u32(0, 2) as usize];
        let mut prev: Option<(f64, Regime)> = None;
        let mut mf = 400.0;
        while mf <= 1000.0 + 1e-9 {
            let p = model::predict(&c, &hw, cf, mf);
            assert!(p.time_us.is_finite() && p.time_us > 0.0);
            if let Some((t_prev, r_prev)) = prev {
                if r_prev != p.regime {
                    let jump = (p.time_us - t_prev).abs() / t_prev;
                    assert!(
                        jump < 0.01,
                        "{:?} -> {:?} jump {:.3}% at cf={cf} mf={mf}: {c:?}",
                        r_prev,
                        p.regime,
                        jump * 100.0
                    );
                    n_boundaries += 1;
                }
            }
            prev = Some((p.time_us, p.regime));
            mf += 0.5;
        }
    }
    assert!(n_boundaries > 5, "memory-axis scan crossed only {n_boundaries} boundaries");
}
