//! Property test: the SoA slab evaluator (`model::soa`) is bit-for-bit
//! identical to the scalar reference (`model::predict`) — not merely
//! close. The SoA layer hoists loop invariants but must never
//! reassociate a floating-point expression, so `to_bits()` equality is
//! the contract, checked across randomized counters, hardware
//! parameters and frequency grids spanning all six pipeline regimes.

use gpufreq::model::soa::{predict_slab, SoaKernel};
use gpufreq::model::{predict, HwParams, KernelCounters, Regime};
use gpufreq::util::prop::Rng;

/// A randomized model instance: counters, hardware, and a frequency
/// slab to evaluate it on.
#[derive(Debug, Clone)]
struct Case {
    c: KernelCounters,
    hw: HwParams,
    core: Vec<f64>,
    mem: Vec<f64>,
}

fn gen_counters(r: &mut Rng) -> KernelCounters {
    // Skew `avr_inst` toward both extremes so the long-compute
    // condition (Eq. 8) flips often; same for `l2_hr` and `aw`, which
    // drive the hidden/saturated conditions.
    let avr_inst = if r.chance(0.5) { r.range(0.005, 1.0) } else { r.range(1.0, 80.0) };
    KernelCounters {
        l2_hr: r.range(0.0, 1.0),
        gld_trans: r.u32(1, 64) as f64,
        avr_inst,
        n_blocks: r.u32(1, 4096) as f64,
        wpb: r.u32(1, 32) as f64,
        aw: r.u32(1, 64) as f64,
        n_sm: r.u32(1, 32) as f64,
        o_itrs: r.u32(1, 256) as f64,
        i_itrs: r.u32(0, 64) as f64,
        uses_smem: r.chance(0.5),
        smem_conflict: r.range(1.0, 8.0),
        gld_body: r.u32(0, 32) as f64,
        gld_edge: r.range(0.0, 8.0),
        mem_ops: r.range(0.0, 8.0),
        l1_hr: r.range(0.0, 1.0),
    }
}

fn gen_hw(r: &mut Rng) -> HwParams {
    HwParams {
        dm_lat_a: r.range(50.0, 500.0),
        dm_lat_b: r.range(10.0, 300.0),
        dm_del: r.range(1.0, 50.0),
        l2_lat: r.range(50.0, 400.0),
        l2_del: r.range(0.5, 20.0),
        sh_lat: r.range(5.0, 100.0),
        inst_cycle: r.range(1.0, 16.0),
    }
}

fn gen_case(r: &mut Rng) -> Case {
    let n = r.u32(1, 24) as usize;
    let mut core = Vec::with_capacity(n);
    let mut mem = Vec::with_capacity(n);
    for _ in 0..n {
        core.push(r.range(100.0, 2000.0));
        mem.push(r.range(100.0, 2000.0));
    }
    Case { c: gen_counters(r), hw: gen_hw(r), core, mem }
}

/// Assert slab == scalar bit-for-bit on every point of `case`, marking
/// each regime the scalar model selects.
fn check_case(case: &Case, seen: &mut [bool; 6]) {
    let slab = predict_slab(&case.c, &case.hw, &case.core, &case.mem);
    assert_eq!(slab.len(), case.core.len());
    for i in 0..case.core.len() {
        let want = predict(&case.c, &case.hw, case.core[i], case.mem[i]);
        seen[want.regime as usize] = true;
        assert_eq!(
            slab.t_active[i].to_bits(),
            want.t_active.to_bits(),
            "t_active diverged at point {i} of {case:?}"
        );
        assert_eq!(
            slab.t_exec_cycles[i].to_bits(),
            want.t_exec_cycles.to_bits(),
            "t_exec_cycles diverged at point {i} of {case:?}"
        );
        assert_eq!(
            slab.time_us[i].to_bits(),
            want.time_us.to_bits(),
            "time_us diverged at point {i} of {case:?}"
        );
        assert_eq!(slab.regime[i], want.regime, "regime diverged at point {i} of {case:?}");
        // The reassembled scalar view agrees with the raw slabs.
        let p = slab.get(i);
        assert_eq!(p.time_us.to_bits(), want.time_us.to_bits());
        assert_eq!(p.regime, want.regime);
    }
}

/// Directed instances aimed at each of the six regimes, so coverage
/// does not hinge on the randomized generator's luck. (The test does
/// not assert which regime each lands in — only identity — but
/// together with the random pool every regime must appear.)
fn directed_cases() -> Vec<Case> {
    let hw = HwParams::paper_defaults();
    let grid: Vec<(f64, f64)> = vec![
        (400.0, 400.0),
        (400.0, 1000.0),
        (1000.0, 400.0),
        (1000.0, 1000.0),
        (1600.0, 300.0),
    ];
    let (core, mem): (Vec<f64>, Vec<f64>) = grid.into_iter().unzip();
    let base = KernelCounters {
        l2_hr: 0.2,
        gld_trans: 4.0,
        avr_inst: 20.0,
        n_blocks: 128.0,
        wpb: 8.0,
        aw: 32.0,
        n_sm: 16.0,
        o_itrs: 16.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 4.0,
        gld_edge: 0.0,
        mem_ops: 1.0,
        l1_hr: 0.0,
    };
    let mk = move |c: KernelCounters| Case { c, hw, core: core.clone(), mem: mem.clone() };
    vec![
        // Compute: heavy per-warp compute, plenty of warps.
        mk(KernelCounters { avr_inst: 60.0, aw: 64.0, ..base }),
        // FewWarpsLongCompute: long compute but a single active warp.
        mk(KernelCounters { avr_inst: 60.0, aw: 1.0, ..base }),
        // Memory: negligible compute, wide transaction queue.
        mk(KernelCounters { avr_inst: 0.01, gld_trans: 32.0, aw: 32.0, ..base }),
        // FewWarpsShortCompute: negligible compute, starved queue.
        mk(KernelCounters { avr_inst: 0.01, gld_trans: 1.0, aw: 2.0, l2_hr: 0.9, ..base }),
        // SmemLight: smem present but the body queue dominates.
        mk(KernelCounters {
            uses_smem: true,
            avr_inst: 0.01,
            gld_body: 16.0,
            aw: 64.0,
            i_itrs: 4.0,
            ..base
        }),
        // SmemIntense: compute-bound smem pipeline.
        mk(KernelCounters {
            uses_smem: true,
            avr_inst: 100.0,
            gld_body: 2.0,
            aw: 16.0,
            i_itrs: 16.0,
            ..base
        }),
    ]
}

#[test]
fn soa_is_bit_identical_to_scalar_across_regimes() {
    let mut seen = [false; 6];
    for case in directed_cases() {
        check_case(&case, &mut seen);
    }
    let mut rng = Rng::new(0xD5F5_C0DE);
    for _ in 0..500 {
        let case = gen_case(&mut rng);
        check_case(&case, &mut seen);
    }
    for id in 0..6u32 {
        assert!(
            seen[id as usize],
            "regime {:?} never exercised — widen the generators",
            Regime::from_id(id).unwrap()
        );
    }
}

#[test]
fn hoisted_kernel_is_reusable_across_slabs() {
    // One SoaKernel, many fills: reuse must not leak state between
    // slabs (the planner evaluates one kernel over per-device grids).
    let mut rng = Rng::new(0xBEEF);
    let case = gen_case(&mut rng);
    let kernel = SoaKernel::new(&case.c, &case.hw);
    let mut out = gpufreq::model::soa::SlabOut::default();
    for split in [1usize, case.core.len() / 2, case.core.len()] {
        let split = split.clamp(1, case.core.len());
        kernel.fill(&case.core[..split], &case.mem[..split], &mut out);
        assert_eq!(out.len(), split);
        for i in 0..split {
            let want = predict(&case.c, &case.hw, case.core[i], case.mem[i]);
            assert_eq!(out.time_us[i].to_bits(), want.time_us.to_bits());
        }
    }
}
