//! Integration tests for the unified prediction engine: backend
//! equivalence, cache semantics (the PR's acceptance criteria), the
//! adapter bridges and the streaming path.

use std::time::Duration;

use gpufreq::baselines::{ConstLatency, PaperModel, Predictor};
use gpufreq::engine::{BatchServer, Engine, EnginePredictor, StreamJob};
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::model::{self, HwParams, KernelCounters};
use gpufreq::profiler;
use gpufreq::sim::{Clocks, GpuSpec};

fn counters() -> KernelCounters {
    KernelCounters {
        l2_hr: 0.15,
        gld_trans: 6.0,
        avr_inst: 2.5,
        n_blocks: 256.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 6.0,
        gld_edge: 0.0,
        mem_ops: 2.0,
        l1_hr: 0.0,
    }
}

#[test]
fn warm_cache_grid_is_bit_identical_to_native_scalar() {
    // Acceptance: the warm-cache predict_grid path returns bit-identical
    // results to NativeScalar and the hit-rate counter is >0 on the
    // second call.
    let hw = HwParams::paper_defaults();
    let engine = Engine::native(hw);
    let c = counters();
    let grid = microbench::standard_grid();

    let cold = engine.predict_grid(&c, &grid).unwrap();
    let warm = engine.predict_grid(&c, &grid).unwrap();
    for (i, (&(cf, mf), (a, b))) in grid.iter().zip(cold.iter().zip(&warm)).enumerate() {
        let want = model::predict(&c, &hw, cf, mf);
        assert_eq!(a.time_us.to_bits(), want.time_us.to_bits(), "cold[{i}]");
        assert_eq!(b.time_us.to_bits(), want.time_us.to_bits(), "warm[{i}]");
        assert_eq!(a.t_active.to_bits(), want.t_active.to_bits());
        assert_eq!(b.t_active.to_bits(), want.t_active.to_bits());
        assert_eq!(a.t_exec_cycles.to_bits(), want.t_exec_cycles.to_bits());
        assert_eq!(b.t_exec_cycles.to_bits(), want.t_exec_cycles.to_bits());
        assert_eq!(a.regime, Some(want.regime));
        assert_eq!(b.regime, Some(want.regime));
    }
    let stats = engine.cache_stats();
    assert!(stats.hits > 0, "second grid call must hit the cache");
    assert_eq!(stats.misses, grid.len() as u64);
    assert!(stats.hit_rate() > 0.0);
}

#[test]
fn all_three_backends_agree_on_the_grid() {
    let hw = HwParams::paper_defaults();
    let c = counters();
    let grid = microbench::standard_grid();
    let native = Engine::builder(hw).scalar().without_cache().build();
    let batch = Engine::builder(hw).batch(4).without_cache().build();
    let pjrt = Engine::pjrt_emulated(hw, 2).unwrap();

    let a = native.predict_grid(&c, &grid).unwrap();
    let b = batch.predict_grid(&c, &grid).unwrap();
    let p = pjrt.predict_grid(&c, &grid).unwrap();
    for i in 0..grid.len() {
        // Native paths are bit-identical.
        assert_eq!(a[i].time_us.to_bits(), b[i].time_us.to_bits());
        // The PJRT path goes through the f32 feature packing: f32-close.
        let rel = (p[i].time_us - a[i].time_us).abs() / a[i].time_us;
        assert!(rel < 1e-4, "pair {i}: pjrt {} vs native {}", p[i].time_us, a[i].time_us);
        assert_eq!(p[i].regime, a[i].regime);
    }
}

#[test]
fn engine_streaming_matches_synchronous_grid() {
    let spec = GpuSpec::default();
    let hw = HwParams::paper_defaults();
    let engine = Engine::native(hw);
    let grid = microbench::standard_grid();
    let ks = [kernels::vector_add(), kernels::matrix_mul_shared(), kernels::black_scholes()];
    let profiles: Vec<_> =
        ks.iter().map(|k| profiler::profile_at(&spec, k, Clocks::new(700.0, 700.0))).collect();

    let jobs: Vec<StreamJob> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| StreamJob { id: i as u64, counters: p.counters, pairs: grid.clone() })
        .collect();
    let mut replies: Vec<_> = engine.predict_stream(jobs).into_iter().collect();
    replies.sort_by_key(|r| r.id);
    assert_eq!(replies.len(), 3);
    for (reply, profile) in replies.iter().zip(&profiles) {
        let ests = reply.result.as_ref().expect("native stream job");
        let sync = engine.predict_grid(&profile.counters, &grid).unwrap();
        for (e, s) in ests.iter().zip(&sync) {
            assert_eq!(e.time_us.to_bits(), s.time_us.to_bits());
        }
    }
}

#[test]
fn predictor_adapter_engine_matches_raw_baseline() {
    let hw = HwParams::paper_defaults();
    let raw = ConstLatency { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 };
    let engine = Engine::from_predictor(
        hw,
        Box::new(ConstLatency { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 }),
    );
    let c = counters();
    let grid = microbench::standard_grid();
    let ests = engine.predict_grid(&c, &grid).unwrap();
    for (e, &(cf, mf)) in ests.iter().zip(&grid) {
        assert_eq!(e.time_us.to_bits(), raw.predict_us(&c, cf, mf).to_bits());
        assert_eq!(e.regime, None, "opaque predictors carry no regime");
    }
    // Warm pass served from cache, still identical.
    let warm = engine.predict_grid(&c, &grid).unwrap();
    assert!(engine.cache_stats().hits >= grid.len() as u64);
    for (a, b) in ests.iter().zip(&warm) {
        assert_eq!(a.time_us.to_bits(), b.time_us.to_bits());
    }
}

#[test]
fn engine_predictor_bridges_back_into_legacy_call_sites() {
    let hw = HwParams::paper_defaults();
    let bridged = EnginePredictor::new(Engine::native(hw), "paper-engine");
    let direct = PaperModel { hw };
    let c = counters();
    for &(cf, mf) in &[(400.0, 400.0), (700.0, 1000.0), (1000.0, 400.0)] {
        assert_eq!(
            bridged.predict_us(&c, cf, mf).to_bits(),
            direct.predict_us(&c, cf, mf).to_bits()
        );
    }
}

#[test]
fn sharded_pjrt_service_survives_concurrent_grids() {
    let hw = HwParams::paper_defaults();
    let (server, _handles) =
        BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(2), 4).unwrap();
    let engine = Engine::builder(hw).pjrt(server.clone()).build();
    let grid = microbench::standard_grid();
    std::thread::scope(|scope| {
        for t in 0..6u32 {
            let engine = engine.clone();
            let grid = grid.clone();
            scope.spawn(move || {
                let mut c = counters();
                c.avr_inst += t as f64; // distinct profiles defeat the cache
                let out = engine.predict_grid(&c, &grid).unwrap();
                assert_eq!(out.len(), 49);
                for e in out {
                    assert!(e.time_us > 0.0 && e.time_us.is_finite());
                }
            });
        }
    });
    assert!(server.stats().requests() >= 6 * 49 - 5 * 49); // at least the misses
    assert_eq!(server.shard_count(), 4);
}

#[test]
fn distinct_hw_params_never_share_cache_entries() {
    let c = counters();
    let hw_a = HwParams::paper_defaults();
    let mut hw_b = HwParams::paper_defaults();
    hw_b.dm_del += 2.0;
    let engine_a = Engine::native(hw_a);
    let engine_b = Engine::native(hw_b);
    let ea = engine_a.predict_one(&c, 700.0, 500.0).unwrap();
    let eb = engine_b.predict_one(&c, 700.0, 500.0).unwrap();
    assert_ne!(ea.time_us.to_bits(), eb.time_us.to_bits());
    assert_eq!(ea.time_us.to_bits(), model::predict(&c, &hw_a, 700.0, 500.0).time_us.to_bits());
    assert_eq!(eb.time_us.to_bits(), model::predict(&c, &hw_b, 700.0, 500.0).time_us.to_bits());
}
