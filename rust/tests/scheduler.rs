//! Streaming-scheduler property tests (DESIGN.md §14) on the virtual
//! clock: over randomized arrival/completion traces — mixed kernels,
//! scales, deadline tightness (including impossible ones), progress
//! reports, early completions, cancellations and device bounces —
//!
//! * every admitted job ends in exactly one terminal state, and a job
//!   that carried a deadline either finished inside it (`Done`) or is
//!   explicitly `Missed` with a recorded cause;
//! * admission and the incremental repair path never disagree with the
//!   full solver: a job repair admits is one the solver can place, and
//!   a job admission rejects is one the solver proves infeasible too;
//! * the drained transition log replays to the same terminal states
//!   the records show, and the stats counters reconcile exactly.

use std::collections::HashMap;
use std::sync::Arc;

use gpufreq::dvfs::PowerModel;
use gpufreq::engine::Engine;
use gpufreq::model::{HwParams, KernelCounters};
use gpufreq::planner::{plan, Job, PlanError, PlannerConfig};
use gpufreq::registry::{DeviceId, DeviceRegistry, KernelCatalog, KernelId};
use gpufreq::scheduler::{Event, JobSpec, JobState, SchedulerConfig, SchedulerCore};
use gpufreq::util::prop::Rng;

fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + 10.0 * (i % 4) as f64,
        n_blocks: 128.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: 0.0,
        mem_ops: 1.0 + (i % 3) as f64,
        l1_hr: 0.0,
    }
}

/// Three devices with distinct hardware and power calibrations, same
/// recipe as the planner's property fixture.
fn fixture() -> (Engine, Vec<DeviceId>, Vec<KernelId>) {
    let hw = HwParams::paper_defaults();
    let registry = Arc::new(DeviceRegistry::new());
    let a = registry.register("stream-a", hw, PowerModel::gtx980());
    let mut hw_b = hw;
    hw_b.dm_del += 1.5;
    let mut power_b = PowerModel::gtx980();
    power_b.leakage.static_w = 15.0;
    let b = registry.register("stream-b", hw_b, power_b);
    let mut hw_c = hw;
    hw_c.l2_lat += 40.0;
    let mut power_c = PowerModel::gtx980();
    power_c.dynamic.core_coeff = 0.05;
    power_c.dynamic.mem_coeff = 0.025;
    let c = registry.register("stream-c", hw_c, power_c);
    let catalog = Arc::new(KernelCatalog::new());
    let kernels: Vec<KernelId> =
        (0..5).map(|i| catalog.register(&format!("k{i}"), counters(i * 3 + 1))).collect();
    let engine = Engine::native(hw).with_handles(registry, catalog, a).unwrap();
    (engine, vec![a, b, c], kernels)
}

fn single_job_config(core: &SchedulerCore) -> PlannerConfig {
    PlannerConfig { telemetry: false, ..core.config().planner.clone() }
}

#[test]
fn random_traces_reach_consistent_terminal_states() {
    let (engine, devices, kernels) = fixture();
    let mut rng = Rng::new(0x5c4ed);
    for case in 0..25 {
        let mut core = SchedulerCore::new(SchedulerConfig {
            replan_interval_us: 5e4,
            horizon_us: 1e7,
            ..SchedulerConfig::default()
        });
        let mut now = 0.0;
        let n = rng.u32(3, 14) as usize;
        for i in 0..n {
            now += rng.range(10.0, 2e4);
            core.run_until(&engine, now);
            // One designated device occasionally drops and comes back:
            // running work on it is displaced, re-placed or missed —
            // never stuck. Only `devices[2]` bounces, so the other two
            // are always up and admission stays comparable to a solver
            // probe over `{devices[0], devices[1]}`.
            if rng.chance(0.15) {
                core.schedule(now, Event::DeviceDown(devices[2]));
                core.schedule(now + rng.range(10.0, 5e4), Event::DeviceUp(devices[2]));
            }
            // Runtime signals on whatever is currently running: a
            // progress observation (refreshes the completion estimate)
            // or an early client-observed completion.
            let running: Vec<u64> = core
                .jobs()
                .iter()
                .filter(|r| r.state == JobState::Running)
                .map(|r| r.id)
                .collect();
            if let Some(&job) = running.first() {
                if rng.chance(0.3) {
                    core.schedule(now, Event::JobProgress { job, fraction: rng.range(0.1, 0.9) });
                } else if rng.chance(0.2) {
                    core.schedule(now, Event::JobCompleted { job });
                }
            }
            let kid = kernels[rng.u32(0, kernels.len() as u32 - 1) as usize];
            let scale = rng.u32(1, 5) as f64;
            let mut spec = JobSpec::new(format!("c{case}-j{i}"), kid, scale);
            let budget = match rng.u32(0, 3) {
                0 => None,                               // unconstrained
                1 => Some(rng.range(1e6, 1e8)),          // generous
                2 => Some(scale * rng.range(50.0, 5e4)), // sometimes binding
                _ => Some(rng.range(1e-3, 5.0)),         // mostly impossible
            };
            if let Some(b) = budget {
                spec = spec.with_deadline(b);
            }
            match core.submit(&engine, spec) {
                Ok(id) => {
                    // Repair (or the queue) took the job — the full
                    // solver, given strictly more freedom (the job
                    // alone, full budget), must agree it is placeable.
                    let mut probe = Job::new(format!("c{case}-j{i}"), kid, scale);
                    if let Some(b) = budget {
                        probe = probe.with_deadline(b);
                    }
                    let solo = plan(&engine, &[probe], &single_job_config(&core));
                    assert!(
                        solo.is_ok(),
                        "case {case}: admitted job is solver-infeasible: {:?}",
                        solo.err()
                    );
                    if rng.chance(0.1) {
                        let rec = core.cancel(&engine, id).expect("known id");
                        assert!(rec.state.is_terminal(), "case {case}: cancel -> {:?}", rec.state);
                    }
                }
                Err(PlanError::Infeasible { .. }) => {
                    // Admission only rejects what the full solver also
                    // proves unmeetable for the job on its own. The
                    // probe plans over the two always-up devices — a
                    // subset of whatever admission saw, so a rejection
                    // must reproduce there.
                    let b = budget.expect("only deadlined jobs are rejected as infeasible");
                    let probe = Job::new(format!("c{case}-j{i}"), kid, scale).with_deadline(b);
                    let cfg = PlannerConfig {
                        devices: Some(vec![devices[0], devices[1]]),
                        ..single_job_config(&core)
                    };
                    assert!(
                        plan(&engine, &[probe], &cfg).is_err(),
                        "case {case}: admission rejected a solver-feasible deadline {b}"
                    );
                }
                Err(e) => panic!("case {case}: unexpected submit error {e}"),
            }
        }
        // Roll far past every deadline and predicted completion: all
        // work must reach a terminal state (no zombie jobs).
        core.run_until(&engine, now + 1e9);

        let s = core.stats();
        assert_eq!(s.submitted, s.admitted + s.rejected, "case {case}: submit split");
        assert_eq!(
            s.admitted,
            s.completed + s.missed + s.cancelled,
            "case {case}: terminal split"
        );
        assert_eq!(s.active, 0, "case {case}: active jobs after drain");
        assert_eq!(s.admitted as usize, core.jobs().len(), "case {case}: record count");

        for r in core.jobs() {
            assert!(
                r.finished_at_us.is_some(),
                "case {case}: job {} terminal without a finish instant",
                r.id
            );
            match r.state {
                JobState::Done => {
                    if let Some(d) = r.deadline_at_us {
                        let f = r.finished_at_us.unwrap();
                        assert!(
                            f <= d + 1e-6,
                            "case {case}: job {} Done at {f} past its deadline {d}",
                            r.id
                        );
                    }
                }
                JobState::Missed => {
                    assert!(
                        r.deadline_at_us.is_some(),
                        "case {case}: job {} Missed without a deadline",
                        r.id
                    );
                    assert!(
                        r.cause.as_ref().is_some_and(|c| !c.is_empty()),
                        "case {case}: job {} Missed without a recorded cause",
                        r.id
                    );
                }
                JobState::Cancelled => {}
                other => panic!("case {case}: job {} left non-terminal ({other:?})", r.id),
            }
        }

        // The transition log must replay to the records' final states:
        // admission first (from: None), monotone timestamps per job,
        // terminal states never left.
        let (transitions, solves) = core.drain_outbox();
        let mut last: HashMap<u64, (JobState, f64)> = HashMap::new();
        for t in &transitions {
            match last.get(&t.job) {
                None => assert!(
                    t.from.is_none() && t.to == JobState::Queued,
                    "case {case}: job {} did not start at admission/Queued",
                    t.job
                ),
                Some(&(prev, at)) => {
                    assert_eq!(t.from, Some(prev), "case {case}: job {} gap in log", t.job);
                    assert!(t.at_us >= at, "case {case}: job {} time went backwards", t.job);
                    assert!(
                        !prev.is_terminal(),
                        "case {case}: job {} left terminal state {prev:?}",
                        t.job
                    );
                }
            }
            last.insert(t.job, (t.to, t.at_us));
        }
        for r in core.jobs() {
            let (state, _) = last[&r.id];
            assert_eq!(state, r.state, "case {case}: log vs record for job {}", r.id);
        }
        for s in &solves {
            assert_eq!(s.jobs, s.job_names.len(), "case {case}: solve job count vs names");
        }
    }
}
