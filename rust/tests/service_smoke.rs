//! Service smoke test (DESIGN.md §9–§13) — the CI job step: boot the
//! HTTP server on an ephemeral port, exercise /healthz, the /v1 shim,
//! the full /v2 handle lifecycle (register device → register kernel →
//! batch predict → advise) and the /v2/plan fleet planner with the
//! in-crate client, check the structured error taxonomy (including the
//! planner's 422 `infeasible`), force the bounded queue to shed a 429,
//! verify the graceful drain, and walk the observability loop:
//! X-Request-Id minting, POST /v2/observations → live `model_mape` in
//! /metrics, GET /debug/traces span dumps, plan provenance behind
//! GET /debug/plans, drift states behind GET /debug/drift, and the
//! `/v2/jobs` streaming-scheduler lifecycle (submit → poll → cancel,
//! 422 `infeasible_at_submit` admission). No curl needed anywhere.

use std::time::{Duration, Instant};

use gpufreq::dvfs::PowerModel;
use gpufreq::engine::Engine;
use gpufreq::microbench;
use gpufreq::model::{HwParams, KernelCounters};
use gpufreq::service::json::Value;
use gpufreq::service::{Client, ClientResponse, Service, ServiceConfig, ServiceState};

fn counters() -> KernelCounters {
    KernelCounters {
        l2_hr: 0.1,
        gld_trans: 6.0,
        avr_inst: 1.5,
        n_blocks: 128.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 6.0,
        gld_edge: 0.0,
        mem_ops: 2.0,
        l1_hr: 0.0,
    }
}

fn state() -> ServiceState {
    let hw = HwParams::paper_defaults();
    let mut s = ServiceState::new(
        Engine::native(hw),
        PowerModel::gtx980(),
        microbench::standard_grid(),
    );
    s.register_kernel("VA", counters());
    s
}

fn cfg(workers: usize, queue_capacity: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        queue_capacity,
        poll_interval: Duration::from_millis(10),
        ..ServiceConfig::default()
    }
}

#[test]
fn healthz_predict_advise_and_metrics_round_trip() {
    let svc = Service::start(state(), cfg(2, 16)).expect("service starts on an ephemeral port");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // GET /healthz
    let r = c.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    let v = r.json().unwrap();
    assert_eq!(v.get("status").and_then(Value::as_str), Some("ok"));
    assert_eq!(v.get("kernels").and_then(Value::as_f64), Some(1.0));

    // POST /v1/predict matches the engine exactly.
    let r = c
        .post("/v1/predict", r#"{"kernel":"VA","core_mhz":800,"mem_mhz":600}"#)
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    let want = Engine::native(HwParams::paper_defaults())
        .predict_one(&counters(), 800.0, 600.0)
        .unwrap();
    assert_eq!(
        v.get("time_us").and_then(Value::as_f64).unwrap().to_bits(),
        want.time_us.to_bits(),
        "served prediction must be bit-identical to the engine"
    );

    // POST /v1/advise returns a feasible best on the default grid.
    let r = c
        .post("/v1/advise", r#"{"kernel":"VA","objective":"energy","deadline_us":1e9}"#)
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
    let best = v.get("best").expect("best config present");
    for key in
        ["core_mhz", "mem_mhz", "time_us", "power_w", "power_dynamic_w", "power_leakage_w",
         "energy_mj"]
    {
        assert!(best.get(key).and_then(Value::as_f64).unwrap() > 0.0, "{key}");
    }

    // GET /metrics reflects the traffic just sent.
    let r = c.get("/metrics").unwrap();
    assert_eq!(r.status, 200);
    for needle in [
        "service_requests_total{route=\"/v1/predict\"} 1",
        "service_requests_total{route=\"/v1/advise\"} 1",
        "service_cache_hits",
        "service_queue_depth",
    ] {
        assert!(r.body.contains(needle), "missing `{needle}` in:\n{}", r.body);
    }

    drop(c);
    svc.shutdown();
}

/// The full v2 handle lifecycle over the wire: register a device,
/// register a kernel, batch-predict across a frequency grid, advise —
/// and every prediction byte-identical to the raw-struct path for the
/// same inputs.
#[test]
fn v2_lifecycle_register_predict_advise_round_trip() {
    let svc = Service::start(state(), cfg(2, 16)).expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // 1. Register a device (hw defaults to the boot GPU's measured
    //    parameters, so predictions are comparable to the raw path).
    let r = c.post("/v2/devices", r#"{"name":"smoke-gpu"}"#).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    let device = v.get("device").and_then(Value::as_str).unwrap().to_string();
    assert_eq!(device, "dev-2", "boot device holds dev-1");

    // 2. Register a kernel with explicit counters.
    let body = r#"{"name":"smoke-kernel","counters":{"l2_hr":0.1,"gld_trans":6,
        "avr_inst":1.5,"n_blocks":128,"wpb":8,"aw":64,"n_sm":16,"o_itrs":8,"mem_ops":2}}"#;
    let r = c.post("/v2/kernels", body).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    let kernel = v.get("kernel").and_then(Value::as_str).unwrap().to_string();
    assert_eq!(kernel, "krn-2", "the boot profile holds krn-1");

    // 3. Batch-predict across a frequency grid in ONE request.
    let grid: Vec<(f64, f64)> = microbench::standard_grid();
    let requests: Vec<String> = grid
        .iter()
        .map(|(cf, mf)| {
            format!(
                r#"{{"device":"{device}","kernel":"{kernel}","core_mhz":{cf},"mem_mhz":{mf}}}"#
            )
        })
        .collect();
    let r = c
        .post("/v2/predict", &format!(r#"{{"requests":[{}]}}"#, requests.join(",")))
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(grid.len() as f64));
    let results = v.get("results").and_then(Value::as_array).unwrap();
    // Byte-identical to the raw-struct path for the same inputs.
    let engine = Engine::native(HwParams::paper_defaults());
    for (res, &(cf, mf)) in results.iter().zip(&grid) {
        let want = engine.predict_one(&counters(), cf, mf).unwrap();
        assert_eq!(
            res.get("time_us").and_then(Value::as_f64).unwrap().to_bits(),
            want.time_us.to_bits(),
            "({cf},{mf})"
        );
        assert_eq!(res.get("device").and_then(Value::as_str), Some(device.as_str()));
        assert_eq!(res.get("kernel").and_then(Value::as_str), Some(kernel.as_str()));
    }

    // 4. Advise on the registered device.
    let r = c
        .post(
            "/v2/advise",
            &format!(r#"{{"device":"{device}","kernel":"{kernel}","deadline_us":1e9}}"#),
        )
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
    assert!(v.get("best").unwrap().get("energy_mj").and_then(Value::as_f64).unwrap() > 0.0);
    assert_eq!(v.get("device").and_then(Value::as_str), Some(device.as_str()));

    // 5. Both registrations are listable.
    let v = c.get("/v2/devices").unwrap().json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));
    let v = c.get("/v2/kernels").unwrap().json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));

    drop(c);
    svc.shutdown();
}

/// `POST /v2/plan` over the wire: register a second device, plan a
/// small deadline-tagged fleet, check the assignment invariants and
/// the baseline comparison, then force a structured 422 infeasibility.
#[test]
fn v2_plan_round_trip_and_infeasibility() {
    let svc = Service::start(state(), cfg(2, 16)).expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // A second, cheaper-idle device so placement is a real choice.
    let r = c
        .post("/v2/devices", r#"{"name":"aux-gpu","power":{"static_w":15.0}}"#)
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);

    let body = r#"{"jobs":[
        {"kernel":"VA","scale":2,"deadline_us":1e9,"name":"nightly"},
        {"kernel":"VA","scale":1},
        {"kernel":"krn-1","scale":3,"deadline_us":5e8}],
        "device_cap":2}"#;
    let r = c.post("/v2/plan", body).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(3.0));
    assert_eq!(v.get("objective").and_then(Value::as_str), Some("energy"));
    let assignments = v.get("assignments").and_then(Value::as_array).unwrap();
    assert_eq!(assignments.len(), 3);
    for a in assignments {
        // Every assignment meets its deadline and satisfies E = P×T.
        let t = a.get("time_us").and_then(Value::as_f64).unwrap();
        if let Some(d) = a.get("deadline_us").and_then(Value::as_f64) {
            assert!(t <= d, "{}", r.body);
        }
        let p = a.get("power_w").and_then(Value::as_f64).unwrap();
        let e = a.get("energy_mj").and_then(Value::as_f64).unwrap();
        assert!((e - p * t * 1e-3).abs() <= 1e-9 * e.max(1.0));
        // The v2 split is reported and sums back to the total.
        let dw = a.get("power_dynamic_w").and_then(Value::as_f64).unwrap();
        let lw = a.get("power_leakage_w").and_then(Value::as_f64).unwrap();
        assert!((dw + lw - p).abs() <= 1e-9 * p, "{dw} + {lw} != {p}");
        let dev = a.get("device").and_then(Value::as_str).unwrap();
        assert!(dev == "dev-1" || dev == "dev-2", "{dev}");
    }
    // The plan never costs more than the max-frequency baseline.
    let total = v.get("total_energy_mj").and_then(Value::as_f64).unwrap();
    let base = v
        .get("baseline")
        .and_then(|b| b.get("total_energy_mj"))
        .and_then(Value::as_f64)
        .unwrap();
    assert!(total <= base, "plan {total} mJ vs baseline {base} mJ");
    assert!(v.get("energy_savings_pct").and_then(Value::as_f64).unwrap() >= 0.0);

    // An impossible deadline is a structured 422, naming the job.
    let r = c
        .post(
            "/v2/plan",
            r#"{"jobs":[{"kernel":"VA","deadline_us":1e-4,"name":"doomed"}]}"#,
        )
        .unwrap();
    assert_eq!(r.status, 422, "{}", r.body);
    assert_eq!(code_of(&r), "infeasible");
    assert!(r.body.contains("doomed"), "{}", r.body);

    // /metrics carries the new route's series.
    let m = c.get("/metrics").unwrap();
    assert!(
        m.body.contains("service_requests_total{route=\"/v2/plan\"} 2"),
        "{}",
        m.body
    );

    drop(c);
    svc.shutdown();
}

fn code_of(r: &ClientResponse) -> String {
    r.json()
        .unwrap_or_else(|e| panic!("non-JSON error body `{}`: {e}", r.body))
        .get("code")
        .and_then(Value::as_str)
        .unwrap_or_else(|| panic!("error body without code: {}", r.body))
        .to_string()
}

/// Error taxonomy: every failure is structured JSON with a stable
/// machine-readable `code`, across 404/405/400 and unknown handles.
#[test]
fn error_taxonomy_is_structured_and_stable() {
    let svc = Service::start(state(), cfg(2, 16)).expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // 404 unknown route.
    let r = c.get("/v3/predict").unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_route"), "{}", r.body);

    // 405 wrong method on a real route, both protocol versions.
    let r = c.get("/v1/predict").unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
    let r = c.post("/healthz", "{}").unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
    let r = c.get("/v2/predict").unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));

    // 400 malformed JSON.
    let r = c.post("/v2/predict", "{not json").unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_json"));
    let r = c.post("/v1/predict", "").unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_json"));

    // 400 well-formed but invalid.
    let r = c.post("/v2/predict", r#"{"requests":[]}"#).unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_request"));

    // Unknown handles on /v2: 404 with specific codes.
    let r = c
        .post(
            "/v2/predict",
            r#"{"requests":[{"device":"dev-77","kernel":"krn-1","core_mhz":700,"mem_mhz":700}]}"#,
        )
        .unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_device"), "{}", r.body);
    let r = c
        .post(
            "/v2/predict",
            r#"{"requests":[{"device":"dev-1","kernel":"krn-77","core_mhz":700,"mem_mhz":700}]}"#,
        )
        .unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_kernel"));
    let r = c.post("/v2/advise", r#"{"device":"ghost","kernel":"VA"}"#).unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_device"));

    // The v1 shim carries codes too (unknown named kernel).
    let r = c
        .post("/v1/predict", r#"{"kernel":"NOPE","core_mhz":700,"mem_mhz":700}"#)
        .unwrap();
    assert_eq!((r.status, code_of(&r).as_str()), (400, "unknown_kernel"));

    drop(c);
    svc.shutdown();
}

#[test]
fn forced_backlog_sheds_429_with_retry_after() {
    // One worker + a 2-deep queue. The worker is pinned by a held-open
    // keep-alive connection; two idle connections fill the queue; the
    // next connection must be shed at admission with 429.
    let svc = Service::start(state(), cfg(1, 2)).unwrap();
    let addr = svc.addr();

    let mut holder = Client::connect(&addr).unwrap();
    holder.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    assert_eq!(holder.get("/healthz").unwrap().status, 200);

    let _queued_a = Client::connect(&addr).unwrap();
    let _queued_b = Client::connect(&addr).unwrap();
    // Let the acceptor enqueue both before probing the high-water mark.
    std::thread::sleep(Duration::from_millis(150));

    let mut probe = Client::connect(&addr).unwrap();
    probe.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Admission control answers without a request being sent.
    let r = probe.read_response().expect("shed response");
    assert_eq!(r.status, 429);
    assert_eq!(r.header("retry-after"), Some("1"));
    assert!(r.body.contains("overloaded"), "{}", r.body);

    let m = svc.metrics();
    assert!(m.shed_total.load(std::sync::atomic::Ordering::SeqCst) >= 1);

    // The pinned worker still serves its connection fine.
    assert_eq!(holder.get("/healthz").unwrap().status, 200);

    drop(holder);
    svc.shutdown();
}

/// The observability loop over the wire (DESIGN.md §13): minted
/// X-Request-Id headers, measured runtimes posted to /v2/observations
/// surfacing as live `model_mape` gauges, and /debug/traces serving
/// newest-first span breakdowns for every admitted request.
#[test]
fn observations_traces_and_request_ids_round_trip() {
    let svc = Service::start(state(), cfg(2, 16)).expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // Every response carries a minted request id (client-supplied echo
    // is covered at unit level in server.rs).
    let r = c.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    let id = r.header("x-request-id").expect("minted request id");
    assert!(id.starts_with("req-"), "{id}");

    // Ingest two observations for the same (device, kernel): one
    // perfectly calibrated, one measured 2x slower than predicted.
    let want = Engine::native(HwParams::paper_defaults())
        .predict_one(&counters(), 700.0, 700.0)
        .unwrap();
    let body = format!(
        r#"{{"observations":[
            {{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":{m}}},
            {{"device":"dev-1","kernel":"krn-1","core_mhz":700,"mem_mhz":700,"measured_us":{m2}}}]}}"#,
        m = want.time_us,
        m2 = 2.0 * want.time_us
    );
    let r = c.post("/v2/observations", &body).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));
    let results = v.get("results").and_then(Value::as_array).unwrap();
    assert_eq!(results[0].get("abs_pct_error").and_then(Value::as_f64), Some(0.0));
    let second = results[1].get("abs_pct_error").and_then(Value::as_f64).unwrap();
    assert!((second - 50.0).abs() < 1e-9, "{second}");

    // /metrics now carries the rolling MAPE ((0 + 50) / 2) and the
    // per-stage latency histograms the traced requests populated.
    let m = c.get("/metrics").unwrap();
    for needle in [
        "model_mape{device=\"dev-1\",kernel=\"krn-1\"} 25.000",
        "model_samples_total{device=\"dev-1\",kernel=\"krn-1\"} 2",
        "service_stage_latency_us_bucket{stage=\"compute\"",
        "service_latency_us_bucket{route=\"/v2/observations\"",
    ] {
        assert!(m.body.contains(needle), "missing `{needle}` in:\n{}", m.body);
    }

    // /debug/traces retains span breakdowns, newest first — the
    // /metrics hit above is the most recent completed request.
    let r = c.get("/debug/traces").unwrap();
    assert_eq!(r.status, 200);
    let v = r.json().unwrap();
    assert!(v.get("count").and_then(Value::as_f64).unwrap() >= 3.0, "{}", r.body);
    let traces = v.get("traces").and_then(Value::as_array).unwrap();
    assert_eq!(traces[0].get("route").and_then(Value::as_str), Some("/metrics"));
    for t in traces {
        let stages = t.get("stages_us").expect("stage breakdown");
        for key in ["accept", "parse", "queue", "compute", "render", "flush"] {
            assert!(stages.get(key).and_then(Value::as_f64).unwrap() >= 0.0, "{key}");
        }
        assert!(t.get("total_us").and_then(Value::as_f64).unwrap() > 0.0);
        assert!(t.get("id").and_then(Value::as_str).is_some());
    }

    drop(c);
    svc.shutdown();
}

/// Plan provenance and drift telemetry over the wire: every `/v2/plan`
/// answer carries a `plan_id` and the solver telemetry block, the solve
/// is retained (with its request id) behind GET /debug/plans, and
/// drifted observations surface worst-first behind GET /debug/drift.
#[test]
fn plan_provenance_and_drift_round_trip() {
    let svc = Service::start(state(), cfg(2, 16)).expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // A solve with one named, deadline-tagged job and one anonymous one.
    let body = r#"{"jobs":[
        {"kernel":"VA","scale":2,"deadline_us":1e9,"name":"nightly"},
        {"kernel":"VA"}]}"#;
    let r = c.post("/v2/plan", body).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let rid = r.header("x-request-id").expect("minted request id").to_string();
    let v = r.json().unwrap();
    let plan_id = v.get("plan_id").and_then(Value::as_str).expect("plan_id").to_string();
    assert!(plan_id.starts_with("plan-"), "{plan_id}");
    let t = v.get("telemetry").expect("telemetry block");
    assert_eq!(t.get("plan_id").and_then(Value::as_str), Some(plan_id.as_str()));
    assert!(
        t.get("phase_us").unwrap().get("total").and_then(Value::as_f64).unwrap() > 0.0,
        "{}",
        r.body
    );
    assert!(
        t.get("counters").unwrap().get("candidates_evaluated").and_then(Value::as_f64).unwrap()
            > 0.0
    );
    let explains = t.get("explains").and_then(Value::as_array).unwrap();
    assert_eq!(explains.len(), 2, "{}", r.body);
    assert_eq!(explains[0].get("name").and_then(Value::as_str), Some("nightly"));
    assert!(explains[0].get("deadline_slack_us").and_then(Value::as_f64).unwrap() >= 0.0);

    // The solve is retained behind /debug/plans, correlated by both ids.
    let r = c.get("/debug/plans").unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(1.0));
    let plans = v.get("plans").and_then(Value::as_array).unwrap();
    assert_eq!(plans[0].get("plan_id").and_then(Value::as_str), Some(plan_id.as_str()));
    assert_eq!(plans[0].get("request_id").and_then(Value::as_str), Some(rid.as_str()));
    assert_eq!(plans[0].get("jobs").and_then(Value::as_f64), Some(2.0));
    assert!(plans[0].get("telemetry").is_some(), "{}", r.body);

    // One calibrated and one badly drifted series → /debug/drift lists
    // the critical one first.
    let want = Engine::native(HwParams::paper_defaults())
        .predict_one(&counters(), 700.0, 700.0)
        .unwrap();
    let obs = format!(
        r#"{{"observations":[
            {{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":{m}}}]}}"#,
        m = 2.0 * want.time_us
    );
    assert_eq!(c.post("/v2/observations", &obs).unwrap().status, 200);
    let r = c.get("/debug/drift").unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(1.0));
    let series = v.get("series").and_then(Value::as_array).unwrap();
    assert_eq!(series[0].get("kernel").and_then(Value::as_str), Some("krn-1"));
    assert_eq!(series[0].get("state").and_then(Value::as_str), Some("critical"));
    assert!(series[0].get("ewma_pct").and_then(Value::as_f64).unwrap() > 25.0);

    // /metrics carries the planner and drift series the above produced.
    let m = c.get("/metrics").unwrap();
    for needle in [
        "planner_solves_total 1",
        "planner_phase_us_count{phase=\"total\"} 1",
        "model_drift_state{device=\"dev-1\",kernel=\"krn-1\"} 2",
        "model_samples_dropped_total 0",
    ] {
        assert!(m.body.contains(needle), "missing `{needle}` in:\n{}", m.body);
    }

    drop(c);
    svc.shutdown();
}

/// The streaming scheduler end-to-end over the wire (DESIGN.md §14):
/// HTTP submit → 202 with a job handle, state transitions observed
/// through GET polls while the server's own ticker advances the
/// lifecycle, DELETE cancels, a provably-unmeetable deadline is a
/// structured 422 at submit, and the listing + /metrics reconcile.
#[test]
fn v2_jobs_streaming_lifecycle_round_trip() {
    let svc = Service::start(
        state(),
        ServiceConfig {
            replan_interval: Duration::from_millis(50),
            horizon: Duration::from_secs(30),
            ..cfg(2, 16)
        },
    )
    .expect("service starts");
    let mut c = Client::connect(&svc.addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // Submit a tiny job under a generous budget: accepted (202) with a
    // handle and already inside the state machine.
    let r = c
        .post("/v2/jobs", r#"{"kernel":"VA","scale":0.001,"name":"etl","deadline_us":1e9}"#)
        .unwrap();
    assert_eq!(r.status, 202, "{}", r.body);
    let v = r.json().unwrap();
    let id = v.get("id").and_then(Value::as_str).unwrap().to_string();
    assert!(id.starts_with("job-"), "{id}");
    let s0 = v.get("state").and_then(Value::as_str).unwrap().to_string();
    assert!(["queued", "scheduled", "running"].contains(&s0.as_str()), "{s0}");

    // Poll the handle until the server's ticker completes it.
    let poll_deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let r = c.get(&format!("/v2/jobs/{id}")).unwrap();
        assert_eq!(r.status, 200, "{}", r.body);
        let v = r.json().unwrap();
        let s = v.get("state").and_then(Value::as_str).unwrap().to_string();
        if s == "done" {
            assert!(
                v.get("finished_at_us").and_then(Value::as_f64).is_some(),
                "done without a finish instant: {}",
                r.body
            );
            break;
        }
        assert!(
            ["queued", "scheduled", "running"].contains(&s.as_str()),
            "unexpected state `{s}`: {}",
            r.body
        );
        assert!(Instant::now() < poll_deadline, "job stuck in `{s}`: {}", r.body);
        std::thread::sleep(Duration::from_millis(20));
    }

    // A huge job pins Running long enough to cancel over the wire.
    let r = c.post("/v2/jobs", r#"{"kernel":"VA","scale":1e9,"name":"hog"}"#).unwrap();
    assert_eq!(r.status, 202, "{}", r.body);
    let hog = r.json().unwrap().get("id").and_then(Value::as_str).unwrap().to_string();
    let r = c.request("DELETE", &format!("/v2/jobs/{hog}"), None).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(
        r.json().unwrap().get("state").and_then(Value::as_str),
        Some("cancelled"),
        "{}",
        r.body
    );

    // Admission control: a provably-unmeetable deadline never reaches
    // the fleet — structured 422 at submit.
    let r = c
        .post("/v2/jobs", r#"{"kernel":"VA","scale":5,"deadline_us":1e-6,"name":"doomed"}"#)
        .unwrap();
    assert_eq!(r.status, 422, "{}", r.body);
    assert_eq!(code_of(&r), "infeasible_at_submit");
    assert!(r.body.contains("provably unmeetable"), "{}", r.body);

    // The listing reconciles: two admitted (done + cancelled), the
    // doomed one rejected without a record.
    let r = c.get("/v2/jobs").unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0), "{}", r.body);
    let stats = v.get("stats").expect("stats block");
    assert_eq!(stats.get("submitted").and_then(Value::as_f64), Some(3.0));
    assert_eq!(stats.get("admitted").and_then(Value::as_f64), Some(2.0));
    assert_eq!(stats.get("rejected").and_then(Value::as_f64), Some(1.0));
    assert_eq!(stats.get("completed").and_then(Value::as_f64), Some(1.0));
    assert_eq!(stats.get("cancelled").and_then(Value::as_f64), Some(1.0));

    // Unknown handles are structured 404s.
    let r = c.get("/v2/jobs/job-99").unwrap();
    assert_eq!(r.status, 404, "{}", r.body);
    assert_eq!(code_of(&r), "unknown_job");

    // /metrics exports the scheduler series.
    let m = c.get("/metrics").unwrap();
    for needle in [
        "scheduler_jobs_submitted_total 3",
        "scheduler_jobs_admitted_total 2",
        "scheduler_jobs_rejected_total 1",
        "scheduler_jobs_completed_total 1",
        "scheduler_jobs_cancelled_total 1",
        "scheduler_jobs_active 0",
    ] {
        assert!(m.body.contains(needle), "missing `{needle}` in:\n{}", m.body);
    }

    drop(c);
    svc.shutdown();
}

#[test]
fn shutdown_drains_within_bounds_and_closes_connections() {
    let svc = Service::start(state(), cfg(2, 8)).unwrap();
    let addr = svc.addr();
    let mut c = Client::connect(&addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    assert_eq!(c.get("/healthz").unwrap().status, 200);
    let t0 = Instant::now();
    svc.shutdown(); // joins the acceptor and every worker
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain must finish promptly, took {:?}",
        t0.elapsed()
    );
    // The worker closed our keep-alive connection during the drain, so
    // the next request observes EOF (or a reset) instead of an answer.
    // (Asserting on the held connection, not on re-connecting to the
    // port — the ephemeral port may be reassigned to a parallel test.)
    c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(
        c.get("/healthz").is_err(),
        "connection must be closed after drain"
    );
}
