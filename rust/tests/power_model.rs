//! Property suite for the v2 power model (DESIGN.md §15): the energy
//! landscape the advisor, planner, and scheduler all price from.
//! Randomized over curves, coefficients, and frequencies:
//!
//! 1. the voltage-dependent leakage excess is monotone nondecreasing
//!    in supply voltage;
//! 2. total board power is monotone nondecreasing in frequency on
//!    flat voltage tables (and on monotone V/f tables);
//! 3. with flat tables and `leak_w = 0`, v2 reproduces the retired
//!    frequency-only v1 formula **bit-for-bit** — the compatibility
//!    guarantee every pre-§15 calibration relies on;
//! 4. the sweep fitter recovers planted parameters to well within 2%.

use gpufreq::dvfs::{DynamicParams, LeakageParams, PowerModel, VfCurve};
use gpufreq::model::fit::fit_power_model;
use gpufreq::util::prop::Rng;

fn random_leakage(r: &mut Rng) -> LeakageParams {
    LeakageParams {
        static_w: r.range(0.0, 40.0),
        leak_w: r.range(0.0, 30.0),
        v_ref: r.range(0.7, 1.2),
        v_slope: r.range(0.3, 1.5),
    }
}

/// A valid random curve: strictly ascending frequencies, voltages
/// constant when `flat`, otherwise nondecreasing.
fn random_curve(r: &mut Rng, flat: bool) -> VfCurve {
    let n = r.u32(1, 6) as usize;
    let mut f = r.range(200.0, 500.0);
    let mut v = r.range(0.7, 0.9);
    let mut pts = Vec::with_capacity(n);
    for _ in 0..n {
        pts.push((f, v));
        f += r.range(50.0, 200.0);
        if !flat {
            v += r.range(0.0, 0.15);
        }
    }
    VfCurve::try_from_points(pts).expect("generator emits valid curves")
}

fn random_dynamic(r: &mut Rng) -> DynamicParams {
    DynamicParams { core_coeff: r.range(0.0, 0.1), mem_coeff: r.range(0.0, 0.05) }
}

#[test]
fn leakage_excess_is_monotone_nondecreasing_in_voltage() {
    let mut r = Rng::new(0x11ab);
    for case in 0..200 {
        let leak = random_leakage(&mut r);
        let mut v = 0.0;
        let mut prev = leak.excess_w(v);
        assert!(prev >= 0.0, "case {case}: negative excess at 0 V");
        for _ in 0..40 {
            v += r.range(0.01, 0.08);
            let e = leak.excess_w(v);
            assert!(
                e >= prev,
                "case {case}: leakage excess fell at {v} V: {e} < {prev} ({leak:?})"
            );
            prev = e;
        }
        // And the anchor: excess == leak_w exactly at v_ref.
        let at_ref = leak.excess_w(leak.v_ref);
        assert!(
            (at_ref - leak.leak_w).abs() <= 1e-12 * leak.leak_w.max(1.0),
            "case {case}: excess at v_ref is {at_ref}, want {}",
            leak.leak_w
        );
    }
}

#[test]
fn total_power_is_monotone_in_frequency_at_fixed_voltage() {
    // On flat tables the voltage terms are constants, so power is
    // affine-increasing in each frequency; the same holds for any
    // monotone V/f table since every term is then nondecreasing in f.
    let mut r = Rng::new(0x22f0);
    for case in 0..150 {
        let flat = case % 2 == 0;
        let model = PowerModel {
            core_curve: random_curve(&mut r, flat),
            mem_curve: random_curve(&mut r, flat),
            dynamic: random_dynamic(&mut r),
            leakage: random_leakage(&mut r),
        };
        let fixed = r.range(100.0, 1500.0);
        let mut f = 50.0;
        let (mut prev_core, mut prev_mem) =
            (model.power_w(f, fixed), model.power_w(fixed, f));
        for _ in 0..30 {
            f += r.range(10.0, 80.0);
            let p_core = model.power_w(f, fixed);
            let p_mem = model.power_w(fixed, f);
            assert!(
                p_core >= prev_core,
                "case {case}: power fell raising core to {f} MHz: {p_core} < {prev_core}"
            );
            assert!(
                p_mem >= prev_mem,
                "case {case}: power fell raising mem to {f} MHz: {p_mem} < {prev_mem}"
            );
            prev_core = p_core;
            prev_mem = p_mem;
        }
    }
}

#[test]
fn flat_tables_and_zero_leakage_reproduce_v1_bit_for_bit() {
    // The retired v1 model was frequency-only: per-domain voltage
    // constants folded into Eq. (1), one static floor, no excess. With
    // flat tables and leak_w = 0, v2 must return the SAME BITS — not
    // merely close — so pre-§15 calibrations price identically.
    let mut r = Rng::new(0x33cc);
    for case in 0..500 {
        let model = PowerModel {
            core_curve: random_curve(&mut r, true),
            mem_curve: random_curve(&mut r, true),
            dynamic: random_dynamic(&mut r),
            leakage: LeakageParams::flat(r.range(0.0, 40.0)),
        };
        assert!(model.core_curve.is_flat() && model.mem_curve.is_flat());
        for _ in 0..4 {
            let cf = r.range(100.0, 1500.0);
            let mf = r.range(100.0, 1500.0);
            let vc = model.core_curve.volts(cf);
            let vm = model.mem_curve.volts(mf);
            // The v1 formula, transcribed literally (same add order).
            let v1 = model.leakage.static_w
                + model.dynamic.core_coeff * cf * vc * vc
                + model.dynamic.mem_coeff * mf * vm * vm;
            let s = model.split_w(cf, mf);
            assert_eq!(
                s.total_w.to_bits(),
                v1.to_bits(),
                "case {case}: v2 diverges from v1 at {cf}/{mf}: {} vs {v1}",
                s.total_w
            );
            assert_eq!(
                s.leakage_w.to_bits(),
                model.leakage.static_w.to_bits(),
                "case {case}: zero-leak_w leakage share must be the static floor alone"
            );
            assert_eq!(
                s.total_w.to_bits(),
                model.power_w(cf, mf).to_bits(),
                "case {case}: split_w and power_w disagree"
            );
        }
    }
}

#[test]
fn sweep_fit_recovers_planted_parameters_within_two_percent() {
    let mut r = Rng::new(0x44d1);
    for case in 0..100 {
        // A voltage-scaled core curve with guaranteed spread (so the
        // leakage regressor is not collinear with the intercept) and a
        // gently-scaling memory curve.
        let mut pts = Vec::new();
        let (mut f, mut v) = (r.range(300.0, 400.0), r.range(0.7, 0.8));
        for _ in 0..r.u32(3, 6) {
            pts.push((f, v));
            f += r.range(100.0, 150.0);
            v += r.range(0.05, 0.12);
        }
        let core_curve = VfCurve::try_from_points(pts).unwrap();
        let mem_curve =
            VfCurve::try_from_points(vec![(400.0, 1.3), (1000.0, r.range(1.35, 1.6))]).unwrap();
        let truth = PowerModel {
            core_curve,
            mem_curve,
            dynamic: DynamicParams {
                core_coeff: r.range(0.01, 0.1),
                mem_coeff: r.range(0.005, 0.05),
            },
            leakage: LeakageParams {
                static_w: r.range(2.0, 30.0),
                leak_w: r.range(2.0, 25.0),
                v_ref: 1.0,
                v_slope: r.range(0.5, 1.2),
            },
        };
        // A noiseless synthetic sweep across both domains.
        let mut samples = Vec::new();
        for i in 0..12 {
            for j in 0..5 {
                let cf = 300.0 + 100.0 * i as f64;
                let mf = 300.0 + 200.0 * j as f64;
                samples.push(((cf, mf), truth.power_w(cf, mf)));
            }
        }
        let fit = fit_power_model(
            &truth.core_curve,
            &truth.mem_curve,
            &samples,
            truth.leakage.v_ref,
            truth.leakage.v_slope,
        )
        .expect("well-posed synthetic sweep");
        let close = |name: &str, got: f64, want: f64| {
            assert!(
                (got - want).abs() <= 0.02 * want.abs().max(1e-9),
                "case {case}: {name} off by more than 2%: fitted {got}, planted {want}"
            );
        };
        close("core_coeff", fit.model.dynamic.core_coeff, truth.dynamic.core_coeff);
        close("mem_coeff", fit.model.dynamic.mem_coeff, truth.dynamic.mem_coeff);
        close("static_w", fit.model.leakage.static_w, truth.leakage.static_w);
        close("leak_w", fit.model.leakage.leak_w, truth.leakage.leak_w);
        assert!(
            fit.r_squared > 0.999,
            "case {case}: noiseless fit should be near-perfect, R² = {}",
            fit.r_squared
        );
        // The fitted model reprices the sweep itself.
        for &((cf, mf), watts) in &samples {
            let p = fit.model.power_w(cf, mf);
            assert!(
                (p - watts).abs() <= 1e-6 * watts.max(1.0),
                "case {case}: fitted model mispredicts its own sweep at {cf}/{mf}"
            );
        }
    }
}
