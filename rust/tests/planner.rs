//! Planner property tests (DESIGN.md §11): over randomized fleets —
//! mixed kernels, scales, deadline tightness (including impossible
//! ones) and capacity pressure — every call either emits a plan that
//! meets **all** deadlines within the concurrency caps, or returns a
//! structured infeasibility naming the blocked job. No third outcome.

use std::sync::Arc;

use gpufreq::dvfs::{DynamicParams, LeakageParams, PowerModel, VfCurve};
use gpufreq::engine::Engine;
use gpufreq::model::{HwParams, KernelCounters};
use gpufreq::planner::{device_grid, max_frequency_baseline, plan, Job, PlanError, PlannerConfig};
use gpufreq::registry::{DeviceId, DeviceRegistry, KernelCatalog, KernelId};
use gpufreq::util::prop::Rng;

fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + 10.0 * (i % 4) as f64,
        n_blocks: 128.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: 0.0,
        mem_ops: 1.0 + (i % 3) as f64,
        l1_hr: 0.0,
    }
}

/// Three devices with distinct hardware and power calibrations.
fn fixture() -> (Engine, Vec<DeviceId>, Vec<KernelId>) {
    let hw = HwParams::paper_defaults();
    let registry = Arc::new(DeviceRegistry::new());
    let a = registry.register("fleet-a", hw, PowerModel::gtx980());
    let mut hw_b = hw;
    hw_b.dm_del += 1.5;
    let mut power_b = PowerModel::gtx980();
    power_b.leakage.static_w = 15.0;
    let b = registry.register("fleet-b", hw_b, power_b);
    let mut hw_c = hw;
    hw_c.l2_lat += 40.0;
    let mut power_c = PowerModel::gtx980();
    power_c.dynamic.core_coeff = 0.05;
    power_c.dynamic.mem_coeff = 0.025;
    let c = registry.register("fleet-c", hw_c, power_c);
    let catalog = Arc::new(KernelCatalog::new());
    let kernels: Vec<KernelId> =
        (0..5).map(|i| catalog.register(&format!("k{i}"), counters(i * 3 + 1))).collect();
    let engine = Engine::native(hw).with_handles(registry, catalog, a).unwrap();
    (engine, vec![a, b, c], kernels)
}

#[test]
fn every_outcome_is_a_valid_plan_or_a_structured_infeasibility() {
    let (engine, devices, kernels) = fixture();
    let mut rng = Rng::new(0x5eed1a);
    let mut plans = 0usize;
    let mut infeasible = 0usize;
    for case in 0..60 {
        let n = rng.u32(1, 24) as usize;
        // Deadline style is drawn per case (a single impossible job
        // already makes a whole fleet infeasible, so per-job draws
        // would leave almost no feasible cases).
        let style = rng.u32(0, 3);
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let kid = kernels[rng.u32(0, kernels.len() as u32 - 1) as usize];
                let scale = (rng.u32(1, 6)) as f64;
                let job = Job::new(format!("c{case}-j{i}"), kid, scale);
                match style {
                    // Unconstrained.
                    0 => job,
                    // Generous budgets — always meetable.
                    1 => job.with_deadline(rng.range(1e7, 1e9)),
                    // Budgets in the plausible range: sometimes bind,
                    // sometimes don't.
                    2 => job.with_deadline(scale * rng.range(50.0, 5e4)),
                    // Mostly impossible.
                    _ => job.with_deadline(rng.range(1e-3, 10.0)),
                }
            })
            .collect();
        // Capacity pressure: from strangling (a few per device) to
        // balanced to unbounded.
        let cap = match rng.u32(0, 2) {
            0 => rng.u32(1, 4) as usize,
            1 => n.div_ceil(devices.len()) + rng.u32(0, 2) as usize,
            _ => usize::MAX,
        };
        let cfg = PlannerConfig { device_cap: cap, ..PlannerConfig::default() };
        match plan(&engine, &jobs, &cfg) {
            Ok(p) => {
                plans += 1;
                assert_eq!(p.assignments.len(), jobs.len(), "case {case}: one per job");
                // Every deadline met, every cap respected, E = P×T.
                assert_eq!(
                    p.deadline_violations(&jobs),
                    0,
                    "case {case}: an emitted plan must meet every deadline"
                );
                for &d in &devices {
                    assert!(
                        p.load_of(d) <= cap,
                        "case {case}: cap {cap} violated on {d}"
                    );
                }
                let mut total = 0.0;
                for (j, a) in p.assignments.iter().enumerate() {
                    assert_eq!(a.job, j, "case {case}: input order preserved");
                    assert!(devices.contains(&a.device));
                    assert!(a.time_us > 0.0 && a.power_w > 0.0);
                    let want = a.power_w * a.time_us * 1e-3;
                    assert!(
                        (a.energy_mj - want).abs() <= 1e-9 * want.max(1.0),
                        "case {case}: E != P*T"
                    );
                    let split = a.power_dynamic_w + a.power_leakage_w;
                    assert!(
                        (split - a.power_w).abs() <= 1e-9 * a.power_w,
                        "case {case}: dynamic + leakage != total power"
                    );
                    total += a.energy_mj;
                }
                assert!(
                    (p.total_energy_mj - total).abs() <= 1e-6 * total.max(1.0),
                    "case {case}: totals must be the sum of assignments"
                );
            }
            Err(PlanError::Infeasible { job, name, detail }) => {
                infeasible += 1;
                assert!(job < jobs.len(), "case {case}: job index {job} out of range");
                assert_eq!(name, jobs[job].name, "case {case}: error names the job");
                assert!(!detail.is_empty());
            }
            Err(other) => {
                panic!("case {case}: valid inputs must never yield {other:?}")
            }
        }
    }
    // The generator must actually exercise both outcomes.
    assert!(plans >= 5, "only {plans} feasible cases — generator drifted");
    assert!(infeasible >= 5, "only {infeasible} infeasible cases — generator drifted");
}

#[test]
fn plans_never_lose_to_the_max_frequency_baseline() {
    // Whenever both the plan and the naive baseline exist and the
    // baseline itself meets every deadline (i.e. it is a feasible
    // solution of the same problem), the planner must cost no more.
    let (engine, devices, kernels) = fixture();
    let mut rng = Rng::new(0xbeef);
    let mut compared = 0usize;
    for _ in 0..30 {
        let n = rng.u32(2, 30) as usize;
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let kid = kernels[rng.u32(0, kernels.len() as u32 - 1) as usize];
                let job = Job::new(format!("j{i}"), kid, (rng.u32(1, 4)) as f64);
                if rng.chance(0.5) {
                    job.with_deadline(rng.range(1e6, 1e9))
                } else {
                    job
                }
            })
            .collect();
        let cap = n.div_ceil(devices.len()) + rng.u32(0, 3) as usize;
        let cfg = PlannerConfig { device_cap: cap, ..PlannerConfig::default() };
        let (Ok(p), Ok(b)) =
            (plan(&engine, &jobs, &cfg), max_frequency_baseline(&engine, &jobs, &cfg))
        else {
            continue;
        };
        if b.deadline_violations(&jobs) > 0 {
            continue;
        }
        compared += 1;
        assert!(
            p.total_energy_mj <= b.total_energy_mj * (1.0 + 1e-9),
            "planned {} mJ must not exceed the feasible baseline {} mJ",
            p.total_energy_mj,
            b.total_energy_mj
        );
    }
    assert!(compared >= 10, "only {compared} comparable cases — generator drifted");
}

#[test]
fn zeroing_leakage_never_raises_the_chosen_frequency() {
    // With FLAT voltage tables the grid is a 1-D ladder over core
    // frequency and leakage contributes a constant adder L to power:
    //   E_L(f) = (P_dyn(f) + S + L)·T(f),  E_0(f) = (P_dyn(f) + S)·T(f).
    // Their difference L·T(f) is nonincreasing in f, so zeroing the
    // leakage can only move the energy argmin DOWN the ladder — the
    // race-to-idle pressure disappears (DESIGN.md §15). Note the claim
    // needs the flat tables: with voltage scaling the grid is 2-D and
    // the adder is no longer constant.
    let hw = HwParams::paper_defaults();
    let core = VfCurve::try_from_points(vec![
        (400.0, 1.0),
        (550.0, 1.0),
        (700.0, 1.0),
        (850.0, 1.0),
        (1000.0, 1.0),
    ])
    .unwrap();
    let mem = VfCurve::try_from_points(vec![(1000.0, 1.0)]).unwrap();
    let leaky = PowerModel {
        core_curve: core,
        mem_curve: mem,
        dynamic: DynamicParams { core_coeff: 0.07, mem_coeff: 0.02 },
        leakage: LeakageParams { static_w: 10.0, leak_w: 25.0, v_ref: 1.0, v_slope: 0.8 },
    };
    let lean = leaky.without_leakage();
    let build = |power: PowerModel| {
        let registry = Arc::new(DeviceRegistry::new());
        let d = registry.register("solo", hw, power);
        let catalog = Arc::new(KernelCatalog::new());
        let kernels: Vec<KernelId> =
            (0..5).map(|i| catalog.register(&format!("k{i}"), counters(i * 3 + 1))).collect();
        let engine = Engine::native(hw).with_handles(registry, catalog, d).unwrap();
        (engine, kernels)
    };
    let (engine_l, kernels_l) = build(leaky);
    let (engine_0, kernels_0) = build(lean);
    assert_eq!(kernels_l, kernels_0, "both catalogs number the kernels identically");
    let mut rng = Rng::new(0x1ea4a6e);
    let mut compared = 0usize;
    for case in 0..20 {
        let n = rng.u32(1, 12) as usize;
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let kid = kernels_l[rng.u32(0, kernels_l.len() as u32 - 1) as usize];
                Job::new(format!("c{case}-j{i}"), kid, rng.u32(1, 5) as f64)
            })
            .collect();
        let cfg = PlannerConfig::default();
        let with = plan(&engine_l, &jobs, &cfg).expect("no deadlines: always feasible");
        let without = plan(&engine_0, &jobs, &cfg).expect("no deadlines: always feasible");
        for (a, b) in with.assignments.iter().zip(&without.assignments) {
            compared += 1;
            assert_eq!(a.job, b.job, "case {case}: same job order");
            assert!(
                b.point.core_mhz <= a.point.core_mhz,
                "case {case} job {}: zeroing leakage raised the clock {} -> {} MHz",
                a.job,
                a.point.core_mhz,
                b.point.core_mhz
            );
        }
    }
    assert!(compared >= 20, "only {compared} placements compared — generator drifted");
}

#[test]
fn solve_reports_are_consistent_and_telemetry_is_passive() {
    // Every feasible solve's SolveReport must be internally consistent
    // — acceptance counters bounded by attempt counters, phase spans
    // summing to no more than the total, and the candidate count equal
    // to distinct-kernels × devices × grid-points — and running the
    // identical problem with telemetry off must produce bit-identical
    // assignments: provenance is an observation, never a perturbation.
    let (engine, devices, kernels) = fixture();
    // All three fixture devices share the gtx980 V/f curves, so each
    // contributes the same frequency grid.
    let grid_points = device_grid(&PowerModel::gtx980()).len();
    let mut rng = Rng::new(0x7e1e5c0e);
    let mut last_plan_id = 0u64;
    for case in 0..25 {
        let n = rng.u32(1, 20) as usize;
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let kid = kernels[rng.u32(0, kernels.len() as u32 - 1) as usize];
                let job = Job::new(format!("c{case}-j{i}"), kid, rng.u32(1, 5) as f64);
                // Generous-or-none deadlines keep every case feasible;
                // infeasibility is another test's property.
                if rng.chance(0.5) {
                    job.with_deadline(rng.range(1e7, 1e9))
                } else {
                    job
                }
            })
            .collect();
        let cap = n.div_ceil(devices.len()) + rng.u32(0, 2) as usize;
        let on_cfg = PlannerConfig { device_cap: cap, ..PlannerConfig::default() };
        let off_cfg = PlannerConfig { telemetry: false, ..on_cfg.clone() };
        let on = plan(&engine, &jobs, &on_cfg).expect("generous deadlines are feasible");
        let off = plan(&engine, &jobs, &off_cfg).expect("same problem, same feasibility");

        // Telemetry is passive: placements agree to the bit.
        assert_eq!(on.assignments.len(), off.assignments.len());
        for (a, b) in on.assignments.iter().zip(&off.assignments) {
            assert_eq!(a.job, b.job, "case {case}");
            assert_eq!(a.device, b.device, "case {case}");
            assert_eq!(a.point.core_mhz.to_bits(), b.point.core_mhz.to_bits(), "case {case}");
            assert_eq!(a.point.mem_mhz.to_bits(), b.point.mem_mhz.to_bits(), "case {case}");
            assert_eq!(a.time_us.to_bits(), b.time_us.to_bits(), "case {case}");
            assert_eq!(a.energy_mj.to_bits(), b.energy_mj.to_bits(), "case {case}");
        }
        assert_eq!(
            on.total_energy_mj.to_bits(),
            off.total_energy_mj.to_bits(),
            "case {case}: totals must agree to the bit"
        );

        // Internal consistency of the telemetry-on report.
        let r = &on.report;
        let distinct = {
            let mut ids: Vec<_> = jobs.iter().map(|j| j.kernel).collect();
            ids.sort();
            ids.dedup();
            ids.len()
        };
        assert_eq!(
            r.candidates_evaluated,
            (distinct * devices.len() * grid_points) as u64,
            "case {case}: candidates = distinct kernels x devices x grid points"
        );
        assert!(r.relocations_accepted <= r.relocations_tried, "case {case}: {r:?}");
        assert!(r.swaps_accepted <= r.swaps_tried, "case {case}: {r:?}");
        assert!(r.total_us > 0.0, "case {case}: telemetry-on solves are timed");
        assert!(
            r.phases_us() <= r.total_us * (1.0 + 1e-9) + 1e-6,
            "case {case}: phase spans exceed the total: {r:?}"
        );
        assert_eq!(r.explains.len(), jobs.len(), "case {case}: one explanation per job");
        for (j, e) in r.explains.iter().enumerate() {
            assert_eq!(e.job, j, "case {case}");
            assert_eq!(e.deadline_slack_us.is_some(), jobs[j].deadline_us.is_some());
            if let Some(s) = e.deadline_slack_us {
                assert!(s >= 0.0, "case {case}: emitted plans meet deadlines, slack {s}");
            }
        }
        // The search itself is deterministic, so the work counters
        // match whether or not the clock was read.
        assert_eq!(r.candidates_evaluated, off.report.candidates_evaluated, "case {case}");
        assert_eq!(r.relocations_tried, off.report.relocations_tried, "case {case}");
        assert_eq!(r.relocations_accepted, off.report.relocations_accepted, "case {case}");
        assert_eq!(r.swaps_tried, off.report.swaps_tried, "case {case}");
        assert_eq!(r.swaps_accepted, off.report.swaps_accepted, "case {case}");
        // Telemetry off: no spans, no provenance, but a fresh id.
        assert_eq!(off.report.total_us, 0.0, "case {case}");
        assert_eq!(off.report.phases_us(), 0.0, "case {case}");
        assert!(off.report.explains.is_empty(), "case {case}");
        assert!(r.plan_id > last_plan_id, "case {case}: ids are monotone");
        assert!(off.report.plan_id > r.plan_id, "case {case}: every solve mints an id");
        last_plan_id = off.report.plan_id;
    }
}
