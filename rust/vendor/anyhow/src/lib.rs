//! Minimal offline stand-in for the `anyhow` crate (DESIGN.md "Offline
//! substitutions"): the vendor set has no registry access, so the small
//! API surface `gpufreq` uses is reimplemented here — `Error` with a
//! context chain, `Result`, the `Context` extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters to callers:
//! `{e}` displays the outermost message, `{e:#}` joins the whole chain
//! with `": "`, and `{e:?}` prints the message plus a `Caused by:` list.

use std::fmt;

/// A context-chained error. `chain[0]` is the outermost (most recent)
/// message; later entries are the causes, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (original) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Disjoint from the generic impl above: `Error` deliberately does not
// implement `std::error::Error` (exactly like upstream anyhow).
impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_modes() {
        let e: Error = Result::<(), _>::Err(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("needs a value").unwrap_err();
        assert_eq!(e.root_cause(), "needs a value");
    }

    #[test]
    fn macros_format() {
        let n = 3;
        let e = anyhow!("bad count {n}");
        assert_eq!(format!("{e}"), "bad count 3");
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag must be set");
            bail!("unreachable for flag=true? no: always bails after ensure passes")
        }
        assert!(f(false).is_err());
        assert!(f(true).is_err());
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "missing file");
    }

    #[test]
    fn nested_context_chain() {
        let e = Error::msg("inner").context("mid").context("outer");
        let chain: Vec<_> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid", "inner"]);
    }
}
