//! Streaming-scheduler bench + gate (DESIGN.md §14): drive a
//! closed-loop arrival stream over both `configs/*.toml` GPUs on the
//! virtual clock and measure what one job event costs. The scheduler's
//! whole argument is that a single arrival should **not** pay the
//! batch solver's K×D×P candidate table — repair prices at most one
//! kernel slab (zero for a kernel already cached) — so the run records
//! the candidate work of every individual submit and compares it
//! against a full re-solve of the same live fleet.
//!
//! **Gate:** every single-job submit event must evaluate strictly
//! fewer candidates than the full re-solve, and the steady-state
//! events (all kernels cached) must evaluate zero. Totals and submit
//! latencies land in `BENCH_scheduler.json` at the repo root.

use std::sync::Arc;
use std::time::Instant;

use gpufreq::engine::Engine;
use gpufreq::model::KernelCounters;
use gpufreq::planner::{plan, Job, PlannerConfig};
use gpufreq::registry::{DeviceRegistry, KernelCatalog, KernelId};
use gpufreq::scheduler::{JobSpec, SchedulerConfig, SchedulerCore, SolveKind};
use gpufreq::service::json::Value;
use gpufreq::util::bench;

const STREAM_EVENTS: usize = 400;
const KERNELS: usize = 8;

/// Synthetic kernel mix sweeping memory-boundedness and compute
/// intensity (the planner bench's recipe), so placement is a real
/// choice per event.
fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + 12.0 * (i % 5) as f64,
        n_blocks: 256.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: (i % 16) as f64,
        uses_smem: i % 3 == 0,
        smem_conflict: 1.0 + (i % 4) as f64,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: (i % 8) as f64,
        mem_ops: 1.0 + (i % 4) as f64,
        l1_hr: 0.0,
    }
}

fn main() {
    bench::section("Scheduler stream: registry setup (per-device §IV probes)");
    let configs = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let registry = Arc::new(DeviceRegistry::new());
    let primary = registry
        .register_from_config(&configs.join("gtx980.toml"))
        .expect("register gtx980");
    registry
        .register_from_config(&configs.join("gtx960.toml"))
        .expect("register gtx960");
    let records = registry.list();
    println!("registered {} devices", records.len());
    assert!(records.len() >= 2, "the stream needs every configs/*.toml device");

    let catalog = Arc::new(KernelCatalog::new());
    let kernel_ids: Vec<KernelId> = (0..KERNELS)
        .map(|i| catalog.register(&format!("stream-{i}"), counters(i * 7 + 1)))
        .collect();
    let hw = registry.get(primary).expect("registered").hw;
    let engine = Engine::native(hw)
        .with_handles(Arc::clone(&registry), Arc::clone(&catalog), primary)
        .expect("attach handles");

    // Mean single-invocation runtime at max frequency per kernel, for
    // arrival pacing and generous (closed-loop, queueing-aware)
    // deadline budgets.
    let max_point = |power: &gpufreq::dvfs::PowerModel| {
        let core = power.core_curve.points.last().expect("non-empty curve").0;
        let mem = power.mem_curve.points.last().expect("non-empty curve").0;
        gpufreq::registry::FreqPoint::new(core, mem)
    };
    let mut worst_max_us = vec![0.0f64; kernel_ids.len()];
    for (ki, &kid) in kernel_ids.iter().enumerate() {
        for rec in &records {
            let t = engine
                .predict_handle(rec.id, kid, max_point(&rec.power))
                .expect("predict at max frequency")
                .time_us;
            worst_max_us[ki] = worst_max_us[ki].max(t);
        }
    }
    let mean_us = worst_max_us.iter().sum::<f64>() / worst_max_us.len() as f64;

    let mut core = SchedulerCore::new(SchedulerConfig {
        replan_interval_us: 50.0 * mean_us,
        horizon_us: 1e6 * mean_us,
        ..SchedulerConfig::default()
    });

    bench::section(&format!(
        "Closed loop: {STREAM_EVENTS} arrivals x {} kernels x {} devices",
        kernel_ids.len(),
        records.len()
    ));
    // Closed loop on the virtual clock: the stream arrives at roughly
    // the fleet's service rate (gap = the arriving job's own runtime
    // share), so completions keep pace with arrivals and the live set
    // stays in steady state instead of growing without bound.
    let mut now = 0.0;
    let mut submit_ns: Vec<f64> = Vec::with_capacity(STREAM_EVENTS);
    let mut event_candidates: Vec<u64> = Vec::with_capacity(STREAM_EVENTS);
    let mut peak_live = 0usize;
    for i in 0..STREAM_EVENTS {
        let ki = i % kernel_ids.len();
        let scale = 1.0 + (i % 7) as f64;
        now += scale * worst_max_us[ki] / records.len() as f64;
        core.run_until(&engine, now);
        let mut job = JobSpec::new(format!("job-{i}"), kernel_ids[ki], scale);
        if i % 3 != 2 {
            // Generous budget: queueing delay must not turn the
            // steady-state stream into a miss parade.
            job = job.with_deadline(8.0 * scale * worst_max_us[ki]);
        }
        let (cand_before, _) = core.table_counters();
        let t0 = Instant::now();
        core.submit(&engine, job).expect("meetable budget is admitted");
        submit_ns.push(t0.elapsed().as_nanos() as f64);
        let (cand_after, _) = core.table_counters();
        event_candidates.push(cand_after - cand_before);
        peak_live = peak_live.max(core.stats().active as usize);
    }
    // Drain: every admitted job reaches a terminal state.
    core.run_until(&engine, now + 1e6 * mean_us);
    let stats = core.stats();
    let (transitions, solves) = core.drain_outbox();
    let repairs = solves.iter().filter(|s| s.kind == SolveKind::Repair).count();
    let fulls = solves.iter().filter(|s| s.kind == SolveKind::Full).count();
    println!(
        "admitted {} · done {} · missed {} · peak live {peak_live} · {} transitions · \
         {repairs} repairs + {fulls} full solves",
        stats.admitted, stats.completed, stats.missed,
        transitions.len()
    );
    assert_eq!(stats.admitted, STREAM_EVENTS as u64, "every arrival is admissible");
    assert_eq!(stats.active, 0, "the drain must terminate every job");

    // ---- The full re-solve foil ----
    // The same kernel mix as one batch: what the scheduler would pay
    // per event without the incremental path. Its candidate table is
    // K distinct kernels x the summed device grids.
    let fleet: Vec<Job> = (0..kernel_ids.len())
        .map(|i| Job::new(format!("batch-{i}"), kernel_ids[i], 1.0 + (i % 7) as f64))
        .collect();
    let full = plan(&engine, &fleet, &PlannerConfig::default()).expect("plannable fleet");
    let full_candidates = full.report.candidates_evaluated;
    println!("full re-solve candidate table: {full_candidates} entries");

    // ---- The gate ----
    // Per single-job event, repair prices at most ONE kernel slab —
    // strictly less than the full table — and once every kernel is
    // cached the steady-state events price zero.
    let max_event = *event_candidates.iter().max().expect("non-empty stream");
    let steady_max = *event_candidates[kernel_ids.len()..].iter().max().expect("stream > K");
    assert!(
        max_event < full_candidates,
        "a single-job event evaluated {max_event} candidates, not strictly fewer than the \
         full re-solve's {full_candidates}"
    );
    assert_eq!(
        steady_max, 0,
        "steady-state submits (all kernels cached) must price no new candidates"
    );
    let (total_candidates, total_slab_calls) = core.table_counters();
    println!(
        "per-event candidates: max {max_event} (first-sight) / {steady_max} (steady state) \
         vs {full_candidates} full · lifetime {total_candidates} candidates, \
         {total_slab_calls} slab calls"
    );

    let mut sorted = submit_ns.clone();
    sorted.sort_by(f64::total_cmp);
    let mean_ns = submit_ns.iter().sum::<f64>() / submit_ns.len() as f64;
    let p50_ns = bench::percentile(&sorted, 0.50);
    let p99_ns = bench::percentile(&sorted, 0.99);
    println!(
        "submit latency: mean {:.1} us · p50 {:.1} us · p99 {:.1} us",
        mean_ns / 1e3,
        p50_ns / 1e3,
        p99_ns / 1e3
    );

    let out = Value::obj(vec![
        ("bench", Value::str("scheduler_stream")),
        ("events", Value::num(STREAM_EVENTS as f64)),
        ("devices", Value::num(records.len() as f64)),
        ("kernels", Value::num(kernel_ids.len() as f64)),
        ("admitted", Value::num(stats.admitted as f64)),
        ("completed", Value::num(stats.completed as f64)),
        ("missed", Value::num(stats.missed as f64)),
        ("peak_live", Value::num(peak_live as f64)),
        ("repairs", Value::num(repairs as f64)),
        ("full_solves", Value::num(fulls as f64)),
        ("repair_fallbacks", Value::num(stats.repair_fallbacks as f64)),
        ("per_event_candidates_max", Value::num(max_event as f64)),
        ("per_event_candidates_steady", Value::num(steady_max as f64)),
        ("full_solve_candidates", Value::num(full_candidates as f64)),
        ("lifetime_candidates", Value::num(total_candidates as f64)),
        ("lifetime_slab_calls", Value::num(total_slab_calls as f64)),
        ("submit_mean_us", Value::num(mean_ns / 1e3)),
        ("submit_p50_us", Value::num(p50_ns / 1e3)),
        ("submit_p99_us", Value::num(p99_ns / 1e3)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_scheduler.json");
    std::fs::write(&path, out.render() + "\n").expect("write BENCH_scheduler.json");
    println!("wrote {}", path.display());
}
