//! Ablation A2 (DESIGN.md §5): throughput of the hot prediction path —
//! batched PJRT artifact vs the scalar native model, plus batch-size
//! scaling of the PJRT path.

use std::time::Duration;

use gpufreq::engine::BatchServer;
use gpufreq::model::{self, HwParams, KernelCounters};
use gpufreq::runtime::Runtime;
use gpufreq::util::bench;

fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + (i % 50) as f64,
        n_blocks: 256.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: (i % 16) as f64,
        uses_smem: i % 3 == 0,
        smem_conflict: 1.0 + (i % 4) as f64,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: (i % 8) as f64,
        mem_ops: 1.0 + (i % 4) as f64,
        l1_hr: 0.0,
    }
}

fn main() {
    let hw = HwParams::paper_defaults();
    let n = 4096usize;
    let cases: Vec<(KernelCounters, f64, f64)> = (0..n)
        .map(|i| (counters(i), 400.0 + (i % 7) as f64 * 100.0, 400.0 + (i / 7 % 7) as f64 * 100.0))
        .collect();

    bench::section("Ablation: prediction-path throughput (4096 rows)");

    let native = bench::bench("native scalar model (4096 rows)", 2, 10, || {
        for (c, cf, mf) in &cases {
            std::hint::black_box(model::predict(c, &hw, *cf, *mf));
        }
    });

    let rt = Runtime::load_or_emulated();
    let rows: Vec<_> = cases.iter().map(|(c, cf, mf)| c.to_features(*cf, *mf)).collect();
    let hw32 = hw.to_f32();
    let pjrt = bench::bench("PJRT batched executor (4096 rows, batch 1024)", 2, 10, || {
        std::hint::black_box(rt.predict(&rows, &hw32).unwrap());
    });

    for chunk in [1usize, 64, 256, 1024] {
        let sub = &rows[..chunk];
        bench::bench(&format!("PJRT one batch, {chunk} live rows"), 2, 10, || {
            std::hint::black_box(rt.predict(sub, &hw32).unwrap());
        });
    }

    // The batching *service* (sharded channels + drain workers) on the
    // same workload.
    let (server, _h) = BatchServer::start_emulated(hw32, Duration::from_millis(1), 2).unwrap();
    let c0 = counters(1);
    let grid: Vec<(f64, f64)> = (0..49)
        .map(|i| (400.0 + (i % 7) as f64 * 100.0, 400.0 + (i / 7) as f64 * 100.0))
        .collect();
    bench::bench("BatchServer.predict_grid (49 rows incl. queueing)", 2, 10, || {
        std::hint::black_box(server.predict_grid(&c0, &grid).unwrap());
    });

    println!(
        "\nnative {:.1}M rows/s vs PJRT {:.1}M rows/s (rows include padding efficiency; the\n\
         PJRT path exists for parity with the AOT stack — see EXPERIMENTS.md §Perf).",
        n as f64 / native.mean_ns * 1e3,
        n as f64 / pjrt.mean_ns * 1e3
    );
}
