//! Bench E4 (paper Table III): DRAM read delay + bandwidth efficiency
//! under frequency scaling, via the saturating bandwidth probe.

use gpufreq::microbench;
use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    bench::section("Table III: DRAM read delay and bandwidth efficiency");
    print!("{}", tables::table3(&spec).ascii());
    println!(
        "paper: dm_del 10.06 -> 9.0 cycles, efficiency 76% -> 85%. Our MC model yields a\n\
         near-constant dm_del/efficiency under joint scaling (second-order GDDR5 effects\n\
         are out of scope — DESIGN.md §2), with the efficiency level inside the paper's band.\n"
    );
    bench::bench("bandwidth probe @700/700", 1, 5, || {
        std::hint::black_box(microbench::bandwidth_probe(&spec, Clocks::new(700.0, 700.0)));
    });
}
