//! Bench E6 (paper Fig. 13): signed prediction error while sweeping one
//! frequency domain with the other fixed — all four panels, all twelve
//! kernels, full ground-truth simulation behind each cell.

use gpufreq::baselines::PaperModel;
use gpufreq::coordinator::validate::validate_with;
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    let model = PaperModel { hw: ex.hw };
    let pairs = microbench::standard_grid();
    let ks = kernels::all();

    bench::section("Fig. 13: time prediction error under different frequency settings");
    let v = validate_with(&spec, &ks, &model, &pairs);
    print!("{}", tables::fig13(&v, Some(400.0), None).ascii());
    print!("{}", tables::fig13(&v, Some(1000.0), None).ascii());
    print!("{}", tables::fig13(&v, None, Some(400.0)).ascii());
    print!("{}", tables::fig13(&v, None, Some(1000.0)).ascii());
    println!(
        "paper shape: every error < 16%, 90% under 10%; ours: max {:.1}%, {:.0}% under 10%.\n",
        v.max_abs_err() * 100.0,
        v.fraction_below(0.10) * 100.0
    );

    bench::bench("full validation (12 kernels x 49 pairs, sim+predict)", 0, 1, || {
        std::hint::black_box(validate_with(&spec, &ks, &model, &pairs));
    });
}
