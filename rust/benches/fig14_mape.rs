//! Bench E7 (paper Fig. 14): per-kernel MAPE over the 49-pair grid and
//! the overall headline (paper: 3.5% overall, 0.7–6.9% per kernel).

use gpufreq::baselines::PaperModel;
use gpufreq::coordinator::validate::validate_with;
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    let model = PaperModel { hw: ex.hw };
    let pairs = microbench::standard_grid();

    bench::section("Fig. 14: MAPE across all frequency pairs (the headline)");
    let v = validate_with(&spec, &kernels::all(), &model, &pairs);
    let (chart, summary) = tables::fig14(&v);
    println!("{chart}");
    print!("{}", summary.ascii());

    assert!(v.overall_mape() < 0.05, "headline regression: {:.2}%", v.overall_mape() * 100.0);

    bench::bench("per-kernel validation (49 pairs each)", 0, 1, || {
        for k in kernels::all() {
            std::hint::black_box(validate_with(&spec, &[k], &model, &pairs));
        }
    });
}
