//! Bench E3 (paper Table II): minimum DRAM latency vs frequency and the
//! Eq. (4) fit — measured on the simulator, fitted both natively and
//! through the AOT PJRT artifact.

use gpufreq::microbench;
use gpufreq::model::fit::fit_line;
use gpufreq::report::tables;
use gpufreq::runtime::Runtime;
use gpufreq::sim::GpuSpec;
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    bench::section("Table II: minimum DRAM latency under frequency scaling");

    let (t, note) = tables::table2(&spec);
    print!("{}", t.ascii());
    println!("{note}\n");

    // Timed: the full 49-pair probe sweep + fit (the §IV extraction).
    let pairs = microbench::standard_grid();
    bench::bench("dm_lat probe sweep (49 pairs) + native fit", 1, 5, || {
        let (r, l) = microbench::dm_lat_sweep(&spec, &pairs);
        std::hint::black_box(fit_line(&r, &l));
    });

    // Cross-check: the PJRT fit artifact returns the same line.
    let (ratios, lats) = microbench::dm_lat_sweep(&spec, &pairs);
    let native = fit_line(&ratios, &lats);
    match Runtime::load_default() {
        Ok(rt) => {
            let r32: Vec<f32> = ratios.iter().map(|&x| x as f32).collect();
            let l32: Vec<f32> = lats.iter().map(|&x| x as f32).collect();
            let (a, b, r2) = rt.fit_dm_lat(&r32, &l32).unwrap();
            println!(
                "fit agreement: native ({:.2}, {:.2}, {:.4}) vs PJRT ({a:.2}, {b:.2}, {r2:.4})",
                native.slope, native.intercept, native.r_squared
            );
            assert!((a - native.slope).abs() < 0.5);
            bench::bench("Eq. (4) fit via PJRT artifact", 2, 20, || {
                std::hint::black_box(rt.fit_dm_lat(&r32, &l32).unwrap());
            });
        }
        Err(e) => println!("(skipping PJRT fit: {e})"),
    }
}
