//! Bench E1 (paper Fig. 2): performance scaling behaviour of the six
//! motivation kernels under one-domain frequency sweeps.

use gpufreq::coordinator::sweep::run_sweep;
use gpufreq::kernels;
use gpufreq::report::tables;
use gpufreq::sim::GpuSpec;
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    let ks = kernels::fig2_set();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    bench::section("Fig. 2: performance scaling under frequency sweeps");
    // The union of all four panels' frequency pairs.
    let mut pairs = Vec::new();
    for i in 4..=10 {
        let f = i as f64 * 100.0;
        pairs.push((400.0, f));
        pairs.push((1000.0, f));
        pairs.push((f, 400.0));
        pairs.push((f, 1000.0));
    }
    pairs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    pairs.dedup();

    let sweep = run_sweep(&spec, &ks, &pairs, workers);
    // Panels (a)/(b): memory sweep at fixed core 400 / 1000.
    print!("{}", tables::fig2(&sweep, &ks, 400.0, true).ascii());
    print!("{}", tables::fig2(&sweep, &ks, 1000.0, true).ascii());
    // Panels (c)/(d): core sweep at fixed memory 400 / 1000.
    print!("{}", tables::fig2(&sweep, &ks, 400.0, false).ascii());
    print!("{}", tables::fig2(&sweep, &ks, 1000.0, false).ascii());
    println!(
        "paper shape: TR/BS/VA/convS reach ~2.5x from memory frequency; MMG/MMS negligible;\n\
         MMG/MMS gain more from memory when the core clock is high (panel b vs a).\n"
    );

    bench::bench("fig2 sweep (6 kernels x 26 pairs)", 0, 3, || {
        std::hint::black_box(run_sweep(&spec, &ks, &pairs, workers));
    });
}
