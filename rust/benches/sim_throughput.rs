//! Simulator performance bench (EXPERIMENTS.md §Perf): simulated-ops/s
//! per kernel and the full 12x49 sweep wall-clock — the L3 hot loop.

use gpufreq::coordinator::sweep::run_sweep;
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::sim::engine::simulate;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    let clocks = Clocks::new(700.0, 700.0);

    bench::section("simulator throughput per kernel (700/700)");
    for k in kernels::all() {
        let ops = k.program.dynamic_len() * k.launch.total_warps();
        let s = bench::bench(&format!("simulate {}", k.name), 1, 5, || {
            std::hint::black_box(simulate(&spec, clocks, &k));
        });
        println!(
            "         {} warp-ops -> {:.1} M warp-ops/s",
            ops,
            ops as f64 / s.mean_ns * 1e3
        );
    }

    bench::section("full ground-truth sweep (12 kernels x 49 pairs)");
    let ks = kernels::all();
    let pairs = microbench::standard_grid();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    bench::bench(&format!("run_sweep on {workers} workers"), 0, 2, || {
        std::hint::black_box(run_sweep(&spec, &ks, &pairs, workers));
    });
    bench::bench("run_sweep single-threaded", 0, 1, || {
        std::hint::black_box(run_sweep(&spec, &ks, &pairs, 1));
    });
}
