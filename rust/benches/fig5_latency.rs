//! Bench E2 (paper Fig. 5): memory-access latency diversity under
//! intensive requests — per-warp first-request latencies ramp linearly
//! with queue position (the FCFS queue signature).

use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    bench::section("Fig. 5: memory access latency under intensive requests");
    let (by_issue, sorted) = tables::fig5(&spec, Clocks::new(700.0, 700.0), 2048);
    print!("{}", by_issue.ascii());
    print!("{}", sorted.ascii());
    println!(
        "paper shape: latencies are diverse (5a) and the sorted curve ramps ~linearly with\n\
         warp rank (5b) — both emerge from the FCFS memory-controller queue.\n"
    );
    bench::bench("fig5 sampled run (2048 warps)", 0, 5, || {
        std::hint::black_box(tables::fig5(&spec, Clocks::new(700.0, 700.0), 2048));
    });
}
