//! Closed-loop load harness for the HTTP prediction service
//! (DESIGN.md §9, experiment E3): N keep-alive connections drive an
//! in-process server as fast as responses return, reporting throughput
//! and exact client-side p50/p99/p999 latency — first over the `/v1`
//! shim, then over the handle-based `/v2/predict` batch route — then a
//! saturation phase verifies 429 shedding and the graceful drain.
//!
//! A third *wide* phase drives the same server with 96 keep-alive
//! connections — 12× the executor pool — exercising the readiness
//! core's whole point: idle connections park on the poll loop instead
//! of each pinning a thread, so the tail (p999) stays bounded far past
//! the worker count.
//!
//! **Perf gates:** the typed v2 path must not cost more than 1.25× the
//! v1 baseline at p99 (plus a small absolute guard for scheduler
//! noise on microsecond-scale percentiles) — handle resolution and the
//! batch envelope are supposed to be bookkeeping, not work. A fourth
//! phase boots three fresh servers — tracing off (`trace_capacity: 0`),
//! tracing on (span ring + request ids), and tracing on with the JSONL
//! event log (`--event-log`) — drives the identical keep-alive workload
//! at each, and asserts both the traced p99 and the event-log p99 stay
//! within 1.10× the untraced baseline: observability that taxes the
//! hot path double-digit percent is observability nobody turns on
//! (DESIGN.md §13). All percentile sets land in
//! `BENCH_service_load.json` at the repo root (`latency_us` is the
//! recorded v1 baseline, `v2_latency_us` the handle path,
//! `wide_latency_us` the 96-connection phase, `traced_latency_us` /
//! `untraced_latency_us` / `events_latency_us` the overhead trio) so
//! the trajectory is tracked across PRs.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use gpufreq::dvfs::PowerModel;
use gpufreq::engine::Engine;
use gpufreq::microbench;
use gpufreq::model::{HwParams, KernelCounters};
use gpufreq::service::json::Value;
use gpufreq::service::{Client, Service, ServiceConfig, ServiceState};
use gpufreq::util::bench::{percentile, section};

/// Total requests over each measured phase (acceptance: ≥ 50k).
const TOTAL_REQUESTS: usize = 60_000;
/// Concurrent closed-loop connections (acceptance: ≥ 8).
const CONNECTIONS: usize = 8;
/// Keep-alive connections in the wide phase (acceptance: ≥ 80) —
/// well past the executor pool, to measure connection multiplexing.
const WIDE_CONNECTIONS: usize = 96;
/// Requests in the wide phase (500 per connection).
const WIDE_REQUESTS: usize = 48_000;
/// p99(v2) must stay within this factor of p99(v1)…
const P99_RATIO_LIMIT: f64 = 1.25;
/// …plus this absolute slack (µs): microsecond-scale percentiles from
/// two sequential phases can differ by a scheduler hiccup alone.
const P99_SLACK_US: f64 = 100.0;
/// Requests per server in the tracing-overhead phase.
const TRACE_REQUESTS: usize = 30_000;
/// p99(traced) must stay within this factor of p99(untraced): the span
/// clock reads, the compute-attribution deltas, and the ring write are
/// budgeted at single-digit percent of a keep-alive request.
const TRACE_RATIO_LIMIT: f64 = 1.10;

fn counters() -> KernelCounters {
    KernelCounters {
        l2_hr: 0.1,
        gld_trans: 6.0,
        avr_inst: 1.5,
        n_blocks: 128.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 6.0,
        gld_edge: 0.0,
        mem_ops: 2.0,
        l1_hr: 0.0,
    }
}

fn state() -> ServiceState {
    let hw = HwParams::paper_defaults();
    let mut s = ServiceState::new(
        Engine::native(hw),
        PowerModel::gtx980(),
        microbench::standard_grid(),
    );
    s.register_kernel("VA", counters());
    s
}

struct Phase {
    latencies_ns: Vec<f64>,
    elapsed: Duration,
}

/// Drive `total` closed-loop requests over `connections` keep-alive
/// connections; `body` maps (thread, iteration) to the request body
/// for `path`.
fn run_phase(
    addr: &SocketAddr,
    path: &'static str,
    connections: usize,
    total: usize,
    body: impl Fn(usize, usize) -> String + Copy + Send,
) -> Phase {
    let per_thread = total.div_ceil(connections);
    let t0 = Instant::now();
    let mut latencies_ns: Vec<f64> = Vec::with_capacity(per_thread * connections);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..connections {
            let addr = *addr;
            handles.push(scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("client connect");
                let mut local = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    let b = body(t, i);
                    let s = Instant::now();
                    let r = c.post(path, &b).expect("request");
                    local.push(s.elapsed().as_nanos() as f64);
                    assert_eq!(r.status, 200, "{}", r.body);
                }
                local
            }));
        }
        for h in handles {
            latencies_ns.extend(h.join().expect("load thread"));
        }
    });
    Phase { latencies_ns, elapsed: t0.elapsed() }
}

struct Summary {
    n: usize,
    elapsed_s: f64,
    throughput: f64,
    mean_us: f64,
    p50_us: f64,
    p99_us: f64,
    p999_us: f64,
}

fn summarize(label: &str, connections: usize, min_n: usize, mut phase: Phase) -> Summary {
    let n = phase.latencies_ns.len();
    assert!(n >= min_n, "must sustain >= {min_n} requests, did {n}");
    phase.latencies_ns.sort_by(f64::total_cmp);
    let throughput = n as f64 / phase.elapsed.as_secs_f64();
    let s = Summary {
        n,
        elapsed_s: phase.elapsed.as_secs_f64(),
        throughput,
        mean_us: phase.latencies_ns.iter().sum::<f64>() / n as f64 / 1e3,
        p50_us: percentile(&phase.latencies_ns, 0.5) / 1e3,
        p99_us: percentile(&phase.latencies_ns, 0.99) / 1e3,
        p999_us: percentile(&phase.latencies_ns, 0.999) / 1e3,
    };
    println!(
        "{label}: {n} requests in {:.2} s  ->  {throughput:.0} req/s over {connections} connections",
        phase.elapsed.as_secs_f64()
    );
    println!(
        "{label}: latency  mean {:.1} us   p50 {:.1} us   p99 {:.1} us   p999 {:.1} us",
        s.mean_us, s.p50_us, s.p99_us, s.p999_us
    );
    s
}

fn latency_json(s: &Summary) -> Value {
    Value::obj(vec![
        ("mean", Value::num(s.mean_us)),
        ("p50", Value::num(s.p50_us)),
        ("p99", Value::num(s.p99_us)),
        ("p999", Value::num(s.p999_us)),
    ])
}

/// Frequencies cycle over the whole cached grid, staggered per
/// connection — identical traffic shape for both protocol phases.
fn freqs(t: usize, i: usize) -> (usize, usize) {
    (400 + 100 * ((t + i) % 7), 400 + 100 * ((t + i / 7) % 7))
}

fn main() {
    section(&format!(
        "Service load: {TOTAL_REQUESTS} requests x 2 protocol phases over {CONNECTIONS} closed-loop connections"
    ));
    let svc = Service::start(
        state(),
        ServiceConfig {
            workers: CONNECTIONS,
            // Admission credit workers + queue_capacity must cover the
            // wide phase's 96 keep-alive connections.
            queue_capacity: 128,
            ..ServiceConfig::default()
        },
    )
    .expect("service starts");
    let addr = svc.addr();

    // Warm the engine cache outside the timer (one grid pass), and
    // pin down the v2 handles: the boot GPU is dev-1, "VA" is krn-1.
    {
        let mut c = Client::connect(&addr).expect("warmup connect");
        let r = c.post("/v1/grid", r#"{"kernel":"VA"}"#).expect("warmup grid");
        assert_eq!(r.status, 200, "warmup failed: {}", r.body);
        let r = c
            .post(
                "/v2/predict",
                r#"{"requests":[{"device":"dev-1","kernel":"krn-1","core_mhz":700,"mem_mhz":700}]}"#,
            )
            .expect("warmup v2");
        assert_eq!(r.status, 200, "v2 warmup failed: {}", r.body);
    }

    // Phase 1: the /v1 shim (the recorded baseline).
    let v1 = summarize(
        "v1/predict",
        CONNECTIONS,
        50_000,
        run_phase(&addr, "/v1/predict", CONNECTIONS, TOTAL_REQUESTS, |t, i| {
            let (cf, mf) = freqs(t, i);
            format!(r#"{{"kernel":"VA","core_mhz":{cf},"mem_mhz":{mf}}}"#)
        }),
    );

    // Phase 2: the typed /v2 handle path, same traffic shape.
    let v2 = summarize(
        "v2/predict",
        CONNECTIONS,
        50_000,
        run_phase(&addr, "/v2/predict", CONNECTIONS, TOTAL_REQUESTS, |t, i| {
            let (cf, mf) = freqs(t, i);
            format!(
                r#"{{"requests":[{{"device":"dev-1","kernel":"krn-1","core_mhz":{cf},"mem_mhz":{mf}}}]}}"#
            )
        }),
    );

    // Phase 3 (wide): 96 keep-alive connections against an 8-thread
    // executor pool — the readiness core multiplexes all of them on
    // the poll loop; the old design would need 96 parked threads.
    section(&format!(
        "Wide keep-alive: {WIDE_REQUESTS} requests over {WIDE_CONNECTIONS} connections, {CONNECTIONS} executors"
    ));
    let wide = summarize(
        "v1/predict wide",
        WIDE_CONNECTIONS,
        WIDE_REQUESTS,
        run_phase(&addr, "/v1/predict", WIDE_CONNECTIONS, WIDE_REQUESTS, |t, i| {
            let (cf, mf) = freqs(t, i);
            format!(r#"{{"kernel":"VA","core_mhz":{cf},"mem_mhz":{mf}}}"#)
        }),
    );
    assert!(
        wide.p999_us.is_finite() && wide.p999_us > 0.0,
        "wide-phase p999 must be measurable, got {}",
        wide.p999_us
    );

    let p99_ratio = v2.p99_us / v1.p99_us;
    println!(
        "v2/v1 p99 ratio: {p99_ratio:.3} (limit {P99_RATIO_LIMIT} + {P99_SLACK_US} us slack)"
    );
    assert!(
        v2.p99_us <= P99_RATIO_LIMIT * v1.p99_us + P99_SLACK_US,
        "v2 handle path p99 {:.1} us exceeds {P99_RATIO_LIMIT}x the v1 baseline {:.1} us",
        v2.p99_us,
        v1.p99_us
    );

    let served = svc.metrics().requests_total();
    assert!(
        served >= (v1.n + v2.n) as u64,
        "server-side count {served} < client-side {}",
        v1.n + v2.n
    );

    // Graceful drain of the loaded server.
    let drain_t0 = Instant::now();
    svc.shutdown();
    let drain = drain_t0.elapsed();
    println!("drained loaded server in {:.0} ms", drain.as_secs_f64() * 1e3);
    assert!(drain < Duration::from_secs(10), "drain took {drain:?}");

    // Phase 4: observability overhead. Three fresh servers, identical
    // traffic: ring off (`trace_capacity: 0` — stage histograms and
    // request-id minting stay on, that is the permanent cost of the
    // feature), ring on, and ring on + the JSONL event log (one
    // request_span record per request through the bounded channel).
    section(&format!(
        "Tracing overhead: {TRACE_REQUESTS} requests x 3 servers (ring off / on / on+events) over {CONNECTIONS} connections"
    ));
    let event_path = std::env::temp_dir()
        .join(format!("gpufreq-service-load-events-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&event_path);
    let trace_phase = |trace_capacity: usize, event_log: Option<std::path::PathBuf>| {
        let events_on = event_log.is_some();
        let svc = Service::start(
            state(),
            ServiceConfig {
                workers: CONNECTIONS,
                queue_capacity: 128,
                trace_capacity,
                slow_us: 0.0,
                event_log,
                ..ServiceConfig::default()
            },
        )
        .expect("trace-phase service starts");
        let addr = svc.addr();
        let mut c = Client::connect(&addr).expect("warmup connect");
        let r = c.post("/v1/grid", r#"{"kernel":"VA"}"#).expect("warmup grid");
        assert_eq!(r.status, 200, "warmup failed: {}", r.body);
        drop(c);
        let phase = run_phase(&addr, "/v1/predict", CONNECTIONS, TRACE_REQUESTS, |t, i| {
            let (cf, mf) = freqs(t, i);
            format!(r#"{{"kernel":"VA","core_mhz":{cf},"mem_mhz":{mf}}}"#)
        });
        // Sanity: the traced server must actually be retaining traces —
        // a gate that "passes" because capture silently never ran
        // measures nothing.
        let mut c = Client::connect(&addr).expect("traces connect");
        let r = c.get("/debug/traces").expect("debug traces");
        assert_eq!(r.status, 200, "{}", r.body);
        let count = r
            .json()
            .expect("traces json")
            .get("count")
            .and_then(Value::as_f64)
            .expect("trace count");
        if trace_capacity == 0 {
            assert_eq!(count, 0.0, "disabled ring must retain nothing");
        } else {
            assert!(count > 0.0, "traced server retained no traces");
        }
        if events_on {
            // Same anti-sleepwalk check for the event log: the gated
            // server must actually be emitting.
            let m = c.get("/metrics").expect("metrics");
            assert!(
                m.body.contains("service_event_log_enabled 1"),
                "event-log server reports the sink disabled"
            );
        }
        drop(c);
        svc.shutdown();
        phase
    };
    let untraced = summarize(
        "v1/predict ring-off",
        CONNECTIONS,
        TRACE_REQUESTS,
        trace_phase(0, None),
    );
    let traced = summarize(
        "v1/predict ring-on",
        CONNECTIONS,
        TRACE_REQUESTS,
        trace_phase(512, None),
    );
    let events = summarize(
        "v1/predict ring-on+events",
        CONNECTIONS,
        TRACE_REQUESTS,
        trace_phase(512, Some(event_path.clone())),
    );
    let trace_ratio = traced.p99_us / untraced.p99_us;
    println!(
        "traced/untraced p99 ratio: {trace_ratio:.3} (limit {TRACE_RATIO_LIMIT} + {P99_SLACK_US} us slack)"
    );
    assert!(
        traced.p99_us <= TRACE_RATIO_LIMIT * untraced.p99_us + P99_SLACK_US,
        "traced p99 {:.1} us exceeds {TRACE_RATIO_LIMIT}x the untraced baseline {:.1} us",
        traced.p99_us,
        untraced.p99_us
    );
    // The event log rides the same budget: a bounded channel hand-off
    // per request must stay inside the tracing gate.
    let events_ratio = events.p99_us / untraced.p99_us;
    println!(
        "events/untraced p99 ratio: {events_ratio:.3} (limit {TRACE_RATIO_LIMIT} + {P99_SLACK_US} us slack)"
    );
    assert!(
        events.p99_us <= TRACE_RATIO_LIMIT * untraced.p99_us + P99_SLACK_US,
        "event-log p99 {:.1} us exceeds {TRACE_RATIO_LIMIT}x the untraced baseline {:.1} us",
        events.p99_us,
        untraced.p99_us
    );
    // The sink was live: the writer thread flushed real JSONL records.
    let event_bytes = std::fs::metadata(&event_path).map(|m| m.len()).unwrap_or(0);
    assert!(event_bytes > 0, "event log is empty after a {TRACE_REQUESTS}-request phase");
    println!("event log: {event_bytes} bytes of JSONL");
    let _ = std::fs::remove_file(&event_path);

    section("Admission control: forced backlog sheds 429");
    // 1 worker + 2-deep queue: a pinned connection and two idle queued
    // ones put the next arrivals over the high-water mark.
    let small = Service::start(
        state(),
        ServiceConfig {
            workers: 1,
            queue_capacity: 2,
            poll_interval: Duration::from_millis(10),
            ..ServiceConfig::default()
        },
    )
    .expect("small service starts");
    let saddr = small.addr();
    let mut holder = Client::connect(&saddr).expect("holder");
    assert_eq!(holder.get("/healthz").expect("healthz").status, 200);
    let _queued_a = Client::connect(&saddr).expect("queued a");
    let _queued_b = Client::connect(&saddr).expect("queued b");
    std::thread::sleep(Duration::from_millis(150));
    let mut shed_429 = 0u64;
    for _ in 0..5 {
        let mut probe = Client::connect(&saddr).expect("probe");
        probe.set_read_timeout(Some(Duration::from_secs(5))).expect("timeout");
        match probe.read_response() {
            Ok(r) if r.status == 429 => {
                assert_eq!(r.header("retry-after"), Some("1"));
                shed_429 += 1;
            }
            Ok(r) => println!("probe got {} (queue had headroom)", r.status),
            Err(e) => println!("probe error: {e}"),
        }
    }
    println!("shed {shed_429}/5 probes with 429 + Retry-After");
    assert!(shed_429 >= 1, "admission control must shed under forced backlog");
    drop(holder);
    let drain2_t0 = Instant::now();
    small.shutdown();
    println!(
        "drained saturated server in {:.0} ms",
        drain2_t0.elapsed().as_secs_f64() * 1e3
    );

    // Machine-readable results at the repo root. `latency_us` stays
    // the v1 baseline (schema-compatible with earlier PRs);
    // `v2_latency_us` and the gate ratio ride alongside.
    let out = Value::obj(vec![
        ("bench", Value::str("service_load")),
        ("requests", Value::num(v1.n as f64)),
        ("connections", Value::num(CONNECTIONS as f64)),
        ("elapsed_s", Value::num(v1.elapsed_s)),
        ("throughput_rps", Value::num(v1.throughput)),
        ("latency_us", latency_json(&v1)),
        ("v2_requests", Value::num(v2.n as f64)),
        ("v2_throughput_rps", Value::num(v2.throughput)),
        ("v2_latency_us", latency_json(&v2)),
        ("v2_p99_over_v1_p99", Value::num(p99_ratio)),
        ("p99_ratio_limit", Value::num(P99_RATIO_LIMIT)),
        ("wide_connections", Value::num(WIDE_CONNECTIONS as f64)),
        ("wide_requests", Value::num(wide.n as f64)),
        ("wide_throughput_rps", Value::num(wide.throughput)),
        ("wide_latency_us", latency_json(&wide)),
        ("untraced_latency_us", latency_json(&untraced)),
        ("traced_latency_us", latency_json(&traced)),
        ("traced_p99_over_untraced_p99", Value::num(trace_ratio)),
        ("trace_ratio_limit", Value::num(TRACE_RATIO_LIMIT)),
        ("events_latency_us", latency_json(&events)),
        ("events_p99_over_untraced_p99", Value::num(events_ratio)),
        ("shed_429", Value::num(shed_429 as f64)),
        ("drain_ms", Value::num(drain.as_secs_f64() * 1e3)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_service_load.json");
    std::fs::write(&path, out.render() + "\n").expect("write BENCH_service_load.json");
    println!("wrote {}", path.display());
}
