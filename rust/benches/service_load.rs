//! Closed-loop load harness for the HTTP prediction service
//! (DESIGN.md §9, experiment E3): N keep-alive connections drive an
//! in-process server as fast as responses return, reporting throughput
//! and exact client-side p50/p99/p999 latency, then a saturation phase
//! verifies 429 shedding and the graceful drain. Results also land in
//! `BENCH_service_load.json` at the repo root so the perf trajectory
//! is tracked across PRs.

use std::time::{Duration, Instant};

use gpufreq::dvfs::PowerModel;
use gpufreq::engine::Engine;
use gpufreq::microbench;
use gpufreq::model::{HwParams, KernelCounters};
use gpufreq::service::json::Value;
use gpufreq::service::{Client, Service, ServiceConfig, ServiceState};
use gpufreq::util::bench::{percentile, section};

/// Total requests over the measured phase (acceptance: ≥ 50k).
const TOTAL_REQUESTS: usize = 60_000;
/// Concurrent closed-loop connections (acceptance: ≥ 8).
const CONNECTIONS: usize = 8;

fn counters() -> KernelCounters {
    KernelCounters {
        l2_hr: 0.1,
        gld_trans: 6.0,
        avr_inst: 1.5,
        n_blocks: 128.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: 0.0,
        uses_smem: false,
        smem_conflict: 1.0,
        gld_body: 6.0,
        gld_edge: 0.0,
        mem_ops: 2.0,
        l1_hr: 0.0,
    }
}

fn state() -> ServiceState {
    let hw = HwParams::paper_defaults();
    let mut s = ServiceState::new(
        Engine::native(hw),
        PowerModel::gtx980(),
        microbench::standard_grid(),
    );
    s.register_kernel("VA", counters());
    s
}

fn main() {
    section(&format!(
        "Service load: {TOTAL_REQUESTS} requests over {CONNECTIONS} closed-loop connections"
    ));
    let svc = Service::start(
        state(),
        ServiceConfig {
            workers: CONNECTIONS,
            queue_capacity: 2 * CONNECTIONS,
            ..ServiceConfig::default()
        },
    )
    .expect("service starts");
    let addr = svc.addr();

    // Warm the engine cache outside the timer (one grid pass).
    {
        let mut c = Client::connect(&addr).expect("warmup connect");
        let r = c.post("/v1/grid", r#"{"kernel":"VA"}"#).expect("warmup grid");
        assert_eq!(r.status, 200, "warmup failed: {}", r.body);
    }

    let per_thread = TOTAL_REQUESTS.div_ceil(CONNECTIONS);
    let t0 = Instant::now();
    let mut latencies_ns: Vec<f64> = Vec::with_capacity(per_thread * CONNECTIONS);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..CONNECTIONS {
            handles.push(scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("client connect");
                let mut local = Vec::with_capacity(per_thread);
                // Cycle frequencies so requests exercise the whole
                // cached grid, staggered per connection.
                for i in 0..per_thread {
                    let cf = 400 + 100 * ((t + i) % 7);
                    let mf = 400 + 100 * ((t + i / 7) % 7);
                    let body =
                        format!(r#"{{"kernel":"VA","core_mhz":{cf},"mem_mhz":{mf}}}"#);
                    let s = Instant::now();
                    let r = c.post("/v1/predict", &body).expect("predict");
                    local.push(s.elapsed().as_nanos() as f64);
                    assert_eq!(r.status, 200, "{}", r.body);
                }
                local
            }));
        }
        for h in handles {
            latencies_ns.extend(h.join().expect("load thread"));
        }
    });
    let elapsed = t0.elapsed();

    let n = latencies_ns.len();
    assert!(n >= 50_000, "must sustain >= 50k requests, did {n}");
    latencies_ns.sort_by(f64::total_cmp);
    let throughput = n as f64 / elapsed.as_secs_f64();
    let p50_us = percentile(&latencies_ns, 0.5) / 1e3;
    let p99_us = percentile(&latencies_ns, 0.99) / 1e3;
    let p999_us = percentile(&latencies_ns, 0.999) / 1e3;
    let mean_us = latencies_ns.iter().sum::<f64>() / n as f64 / 1e3;
    println!(
        "served {n} requests in {:.2} s  ->  {throughput:.0} req/s over {CONNECTIONS} connections",
        elapsed.as_secs_f64()
    );
    println!(
        "latency  mean {mean_us:.1} us   p50 {p50_us:.1} us   p99 {p99_us:.1} us   p999 {p999_us:.1} us"
    );
    let served = svc.metrics().requests_total();
    assert!(served >= n as u64, "server-side count {served} < client-side {n}");

    // Graceful drain of the loaded server.
    let drain_t0 = Instant::now();
    svc.shutdown();
    let drain = drain_t0.elapsed();
    println!("drained loaded server in {:.0} ms", drain.as_secs_f64() * 1e3);
    assert!(drain < Duration::from_secs(10), "drain took {drain:?}");

    section("Admission control: forced backlog sheds 429");
    // 1 worker + 2-deep queue: a pinned connection and two idle queued
    // ones put the next arrivals over the high-water mark.
    let small = Service::start(
        state(),
        ServiceConfig {
            workers: 1,
            queue_capacity: 2,
            poll_interval: Duration::from_millis(10),
            ..ServiceConfig::default()
        },
    )
    .expect("small service starts");
    let saddr = small.addr();
    let mut holder = Client::connect(&saddr).expect("holder");
    assert_eq!(holder.get("/healthz").expect("healthz").status, 200);
    let _queued_a = Client::connect(&saddr).expect("queued a");
    let _queued_b = Client::connect(&saddr).expect("queued b");
    std::thread::sleep(Duration::from_millis(150));
    let mut shed_429 = 0u64;
    for _ in 0..5 {
        let mut probe = Client::connect(&saddr).expect("probe");
        probe.set_read_timeout(Some(Duration::from_secs(5))).expect("timeout");
        match probe.read_response() {
            Ok(r) if r.status == 429 => {
                assert_eq!(r.header("retry-after"), Some("1"));
                shed_429 += 1;
            }
            Ok(r) => println!("probe got {} (queue had headroom)", r.status),
            Err(e) => println!("probe error: {e}"),
        }
    }
    println!("shed {shed_429}/5 probes with 429 + Retry-After");
    assert!(shed_429 >= 1, "admission control must shed under forced backlog");
    drop(holder);
    let drain2_t0 = Instant::now();
    small.shutdown();
    println!(
        "drained saturated server in {:.0} ms",
        drain2_t0.elapsed().as_secs_f64() * 1e3
    );

    // Machine-readable results at the repo root.
    let out = Value::obj(vec![
        ("bench", Value::str("service_load")),
        ("requests", Value::num(n as f64)),
        ("connections", Value::num(CONNECTIONS as f64)),
        ("elapsed_s", Value::num(elapsed.as_secs_f64())),
        ("throughput_rps", Value::num(throughput)),
        (
            "latency_us",
            Value::obj(vec![
                ("mean", Value::num(mean_us)),
                ("p50", Value::num(p50_us)),
                ("p99", Value::num(p99_us)),
                ("p999", Value::num(p999_us)),
            ]),
        ),
        ("shed_429", Value::num(shed_429 as f64)),
        ("drain_ms", Value::num(drain.as_secs_f64() * 1e3)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_service_load.json");
    std::fs::write(&path, out.render() + "\n").expect("write BENCH_service_load.json");
    println!("wrote {}", path.display());
}
