//! Engine bench (DESIGN.md §5, experiment E2): cold vs warm
//! `predict_grid` on a 13×13 frequency grid, per backend, plus the
//! scoped-thread batch backend on a sweep-sized workload. Drives
//! `util::bench` (criterion substitute, harness = false).

use gpufreq::engine::{Backend, Engine, NativeBatch, Request};
use gpufreq::model::{HwParams, KernelCounters};
use gpufreq::service::json::Value;
use gpufreq::util::bench::{self, Stats};

fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + (i % 50) as f64,
        n_blocks: 256.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: (i % 16) as f64,
        uses_smem: i % 3 == 0,
        smem_conflict: 1.0 + (i % 4) as f64,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: (i % 8) as f64,
        mem_ops: 1.0 + (i % 4) as f64,
        l1_hr: 0.0,
    }
}

/// 13×13 grid: 400–1000 MHz at 50 MHz stride on both axes.
fn grid_13x13() -> Vec<(f64, f64)> {
    let steps: Vec<f64> = (0..13).map(|i| 400.0 + i as f64 * 50.0).collect();
    let mut out = Vec::with_capacity(169);
    for &cf in &steps {
        for &mf in &steps {
            out.push((cf, mf));
        }
    }
    out
}

/// One bench result as a JSON object (grid timings normalized to
/// per-pair throughput).
fn stats_json(s: &Stats, pairs_per_iter: usize) -> Value {
    Value::obj(vec![
        ("mean_ms", Value::num(s.mean_ns / 1e6)),
        ("p50_ms", Value::num(s.p50_ns / 1e6)),
        ("p99_ms", Value::num(s.p99_ns / 1e6)),
        (
            "pairs_per_s",
            Value::num(pairs_per_iter as f64 / (s.mean_ns / 1e9)),
        ),
    ])
}

fn main() {
    let hw = HwParams::paper_defaults();
    let grid = grid_13x13();
    let c0 = counters(1);

    bench::section("Engine cache: cold vs warm predict_grid (13x13 = 169 pairs)");

    // Cold: a fresh engine per iteration, every pair is a miss.
    let cold = bench::bench("cold grid (native-scalar, fresh cache)", 2, 20, || {
        let engine = Engine::native(hw);
        std::hint::black_box(engine.predict_grid(&c0, &grid).unwrap());
    });

    // Warm: one engine, the first pass primed outside the timer.
    let warm_engine = Engine::native(hw);
    warm_engine.predict_grid(&c0, &grid).unwrap();
    let warm = bench::bench("warm grid (native-scalar, all hits)", 2, 20, || {
        std::hint::black_box(warm_engine.predict_grid(&c0, &grid).unwrap());
    });
    let s = warm_engine.cache_stats();
    println!(
        "cache after warm runs: {} hits / {} misses ({:.1}% hit rate, {} entries)",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0,
        s.entries
    );
    assert!(s.hit_rate() > 0.9, "warm loop must be cache-served");

    // Uncached reference: the same grid with memoization disabled.
    let uncached = Engine::builder(hw).scalar().without_cache().build();
    let uncached_stats = bench::bench("uncached grid (native-scalar)", 2, 20, || {
        std::hint::black_box(uncached.predict_grid(&c0, &grid).unwrap());
    });

    bench::section("Engine backends: sweep-sized batch (4096 distinct rows)");
    let reqs: Vec<Request> = (0..4096)
        .map(|i| Request {
            counters: counters(i),
            core_mhz: 400.0 + (i % 13) as f64 * 50.0,
            mem_mhz: 400.0 + (i / 13 % 13) as f64 * 50.0,
        })
        .collect();
    // Straight through Backend::predict_batch: every row keeps its own
    // counters, so this measures backend throughput on genuinely
    // distinct inputs (no cache in this path).
    let mut batch8: Option<Stats> = None;
    for workers in [1usize, 2, 4, 8] {
        let backend = NativeBatch::new(hw, workers);
        let s = bench::bench(&format!("native-batch predict ({workers} workers)"), 1, 10, || {
            std::hint::black_box(backend.predict_batch(&reqs).unwrap());
        });
        if workers == 8 {
            batch8 = Some(s);
        }
    }

    bench::section("Engine backends: PJRT service grid (169 pairs, 2 workers)");
    let pjrt = Engine::pjrt_emulated(hw, 2).unwrap();
    pjrt.predict_grid(&c0, &grid).unwrap(); // spin-up outside the timer
    bench::bench("pjrt-emulated warm grid", 1, 10, || {
        std::hint::black_box(pjrt.predict_grid(&c0, &grid).unwrap());
    });

    // Machine-readable results at the repo root (perf trajectory
    // tracking — see BENCH_service_load.json for the serving layer).
    let out = Value::obj(vec![
        ("bench", Value::str("engine_cache")),
        ("grid_pairs", Value::num(grid.len() as f64)),
        ("cold_grid", stats_json(&cold, grid.len())),
        ("warm_grid", stats_json(&warm, grid.len())),
        ("uncached_grid", stats_json(&uncached_stats, grid.len())),
        (
            "native_batch_8_workers",
            stats_json(&batch8.expect("8-worker run recorded"), reqs.len()),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_engine_cache.json");
    std::fs::write(&path, out.render() + "\n").expect("write BENCH_engine_cache.json");
    println!("wrote {}", path.display());
}
