//! Fleet-planner bench + gate (DESIGN.md §11): plan a synthetic fleet
//! of 240 jobs over every registered device (the two `configs/*.toml`
//! GPUs, parameters measured per device by the §IV probes) and assert
//! the planner strictly beats the run-everything-at-max-frequency
//! baseline on total energy while meeting **every** deadline. Timings
//! and totals land in `BENCH_planner.json` at the repo root.
//!
//! A second phase measures raw candidate-table throughput — the
//! planner's dominant cost — two ways over the identical K×D×P
//! workload: the scalar baseline (build a `Vec<Request>`, evaluate
//! through `NativeScalar::predict_batch`, one struct walk per point)
//! versus the SoA slab path (`model::soa::predict_slab`, invariants
//! hoisted once per (device, kernel)). **Gate:** the SoA path must
//! sustain ≥ 2× the scalar baseline's tuples/s in the same run.

use std::sync::Arc;
use std::time::Instant;

use gpufreq::engine::{Backend, Engine, NativeScalar, Request};
use gpufreq::model::{soa, KernelCounters};
use gpufreq::planner::{plan, plan_with_baseline, Job, PlannerConfig};
use gpufreq::registry::{DeviceRegistry, KernelCatalog, KernelId};
use gpufreq::service::json::Value;
use gpufreq::util::bench;

const FLEET_JOBS: usize = 240;

/// Synthetic kernel mix: the index sweeps memory-boundedness (l2 hit
/// rate, transaction count) and compute intensity, so the fleet spans
/// the paper's regimes and device/frequency choice genuinely matters.
fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + 12.0 * (i % 5) as f64,
        n_blocks: 256.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: (i % 16) as f64,
        uses_smem: i % 3 == 0,
        smem_conflict: 1.0 + (i % 4) as f64,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: (i % 8) as f64,
        mem_ops: 1.0 + (i % 4) as f64,
        l1_hr: 0.0,
    }
}

fn main() {
    bench::section("Planner fleet: registry setup (per-device §IV probes)");
    let configs = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let registry = Arc::new(DeviceRegistry::new());
    let primary = registry
        .register_from_config(&configs.join("gtx980.toml"))
        .expect("register gtx980");
    registry
        .register_from_config(&configs.join("gtx960.toml"))
        .expect("register gtx960");
    let records = registry.list();
    println!("registered {} devices", records.len());
    assert!(records.len() >= 2, "the fleet needs every configs/*.toml device");

    let catalog = Arc::new(KernelCatalog::new());
    let kernel_ids: Vec<KernelId> =
        (0..8).map(|i| catalog.register(&format!("synth-{i}"), counters(i * 7 + 1))).collect();

    let hw = registry.get(primary).expect("registered").hw;
    let engine = Engine::native(hw)
        .with_handles(Arc::clone(&registry), Arc::clone(&catalog), primary)
        .expect("attach handles");

    // Deadlines must be meetable on ANY device (so zero violations is a
    // planner guarantee, not luck): budget = headroom × the job's
    // worst-device runtime at max frequency. headroom cycles through
    // tight/medium/loose; a third of the fleet runs unconstrained.
    let max_point = |power: &gpufreq::dvfs::PowerModel| {
        let core = power.core_curve.points.last().expect("non-empty curve").0;
        let mem = power.mem_curve.points.last().expect("non-empty curve").0;
        gpufreq::registry::FreqPoint::new(core, mem)
    };
    let mut worst_max_us = vec![0.0f64; kernel_ids.len()];
    for (ki, &kid) in kernel_ids.iter().enumerate() {
        for rec in &records {
            let t = engine
                .predict_handle(rec.id, kid, max_point(&rec.power))
                .expect("predict at max frequency")
                .time_us;
            worst_max_us[ki] = worst_max_us[ki].max(t);
        }
    }

    let headrooms = [1.1, 1.5, 2.5];
    let jobs: Vec<Job> = (0..FLEET_JOBS)
        .map(|i| {
            let ki = i % kernel_ids.len();
            let scale = 1.0 + (i % 7) as f64;
            let job = Job::new(format!("job-{i}"), kernel_ids[ki], scale);
            if i % 3 == 2 {
                job
            } else {
                let headroom = headrooms[(i / 3) % headrooms.len()];
                job.with_deadline(headroom * scale * worst_max_us[ki])
            }
        })
        .collect();
    assert!(jobs.len() >= 200, "the gate is defined over a >=200 job fleet");

    // Balanced per-device concurrency cap.
    let cap = jobs.len().div_ceil(records.len());
    let cfg = PlannerConfig { device_cap: cap, ..PlannerConfig::default() };

    bench::section(&format!(
        "Planner fleet: {} jobs x {} devices (cap {cap}/device)",
        jobs.len(),
        records.len()
    ));
    // Warm pass outside the timer primes the engine's grid cache and
    // produces the plan under test (one evaluation pass covers the
    // baseline too).
    let (planned, naive) = plan_with_baseline(&engine, &jobs, &cfg).expect("plannable fleet");
    let naive = naive.expect("round-robin baseline is placeable under a balanced cap");
    let solve = bench::bench("plan (warm engine cache)", 1, 10, || {
        std::hint::black_box(plan(&engine, &jobs, &cfg).expect("plannable"));
    });
    // Same fleet with the telemetry clock reads and the provenance pass
    // disabled: the observability tax on a solve must stay within 10%.
    let off_cfg = PlannerConfig { telemetry: false, ..cfg.clone() };
    let solve_off = bench::bench("plan (telemetry off)", 1, 10, || {
        std::hint::black_box(plan(&engine, &jobs, &off_cfg).expect("plannable"));
    });

    // ---- The gate ----
    let violations = planned.deadline_violations(&jobs);
    assert_eq!(violations, 0, "an emitted plan must meet every deadline");
    assert!(
        planned.total_energy_mj < naive.total_energy_mj,
        "planner energy {} mJ must be strictly below the max-frequency baseline {} mJ",
        planned.total_energy_mj,
        naive.total_energy_mj
    );
    for rec in &records {
        let load = planned.load_of(rec.id);
        assert!(load <= cap, "cap violated on {}: {load} > {cap}", rec.id);
    }
    let saved_pct = planned.energy_savings_pct_vs(&naive);
    println!(
        "plan {:.1} mJ vs baseline {:.1} mJ ({saved_pct:.1}% saved, {} local-search steps, \
         0 violations)",
        planned.total_energy_mj, naive.total_energy_mj, planned.swaps_applied
    );
    let cache = engine.cache_stats();
    println!(
        "engine cache: {} hits / {} misses ({} entries)",
        cache.hits, cache.misses, cache.entries
    );

    // ---- Telemetry-overhead gate ----
    // Spans + provenance must be effectively free: a telemetry-on solve
    // may cost at most 1.10x the telemetry-off solve of the same fleet.
    const TELEMETRY_RATIO_LIMIT: f64 = 1.10;
    // Sub-millisecond solves are noise-dominated; gate on means with an
    // absolute floor so a fast machine cannot fail on scheduler jitter.
    let telemetry_ratio = solve.mean_ns / solve_off.mean_ns.max(1.0);
    let overhead_ms = (solve.mean_ns - solve_off.mean_ns) / 1e6;
    println!(
        "telemetry on {:.2} ms vs off {:.2} ms ({telemetry_ratio:.3}x, {overhead_ms:+.3} ms)",
        solve.mean_ns / 1e6,
        solve_off.mean_ns / 1e6
    );
    assert!(
        telemetry_ratio <= TELEMETRY_RATIO_LIMIT || overhead_ms <= 0.5,
        "solver telemetry costs {telemetry_ratio:.3}x (limit {TELEMETRY_RATIO_LIMIT}x, \
         overhead {overhead_ms:.3} ms)"
    );
    // Telemetry is passive: both solves place every job identically.
    let off_plan = plan(&engine, &jobs, &off_cfg).expect("plannable");
    assert_eq!(off_plan.total_energy_mj.to_bits(), planned.total_energy_mj.to_bits());

    // ---- Candidate-table throughput: scalar vs SoA ----
    // The identical K×D×P workload both ways: every synthetic kernel on
    // every device over a dense frequency grid.
    let mut grid_core: Vec<f64> = Vec::new();
    let mut grid_mem: Vec<f64> = Vec::new();
    for ci in 0..60 {
        for mi in 0..60 {
            grid_core.push(400.0 + 15.0 * ci as f64);
            grid_mem.push(300.0 + 12.0 * mi as f64);
        }
    }
    let points = grid_core.len();
    let kernel_counters: Vec<KernelCounters> = (0..8).map(|i| counters(i * 7 + 1)).collect();
    let tuples_per_pass = kernel_counters.len() * records.len() * points;
    bench::section(&format!(
        "Candidate-table throughput: {} kernels x {} devices x {points} points = {tuples_per_pass} tuples/pass",
        kernel_counters.len(),
        records.len()
    ));
    const PASSES: usize = 5;
    let scalar_backends: Vec<NativeScalar> =
        records.iter().map(|rec| NativeScalar::new(rec.hw)).collect();

    // Scalar baseline: per (device, kernel) build the request tuples
    // and walk them one struct at a time — the pre-SoA table build.
    let mut sink = 0.0f64;
    let t0 = Instant::now();
    for _ in 0..PASSES {
        for backend in &scalar_backends {
            for c in &kernel_counters {
                let reqs: Vec<Request> = grid_core
                    .iter()
                    .zip(&grid_mem)
                    .map(|(&cf, &mf)| Request { counters: *c, core_mhz: cf, mem_mhz: mf })
                    .collect();
                let ests = backend.predict_batch(&reqs).expect("scalar batch");
                sink += ests[0].time_us;
            }
        }
    }
    let scalar_s = t0.elapsed().as_secs_f64();
    let scalar_tuples_per_s = (PASSES * tuples_per_pass) as f64 / scalar_s;

    // SoA path: hoist invariants once per (device, kernel), then run
    // the frequency slabs straight through.
    let t1 = Instant::now();
    for _ in 0..PASSES {
        for rec in &records {
            for c in &kernel_counters {
                let slab = soa::predict_slab(c, &rec.hw, &grid_core, &grid_mem);
                sink += slab.time_us[0];
            }
        }
    }
    let soa_s = t1.elapsed().as_secs_f64();
    let soa_tuples_per_s = (PASSES * tuples_per_pass) as f64 / soa_s;
    std::hint::black_box(sink);

    let soa_speedup = soa_tuples_per_s / scalar_tuples_per_s;
    println!(
        "scalar {scalar_tuples_per_s:.0} tuples/s vs SoA {soa_tuples_per_s:.0} tuples/s \
         ({soa_speedup:.2}x)"
    );
    assert!(
        soa_tuples_per_s >= 2.0 * scalar_tuples_per_s,
        "SoA table build must sustain >= 2x scalar throughput, got {soa_speedup:.2}x \
         ({soa_tuples_per_s:.0} vs {scalar_tuples_per_s:.0} tuples/s)"
    );

    // ---- Power-model phase: v2 (leakage-aware) vs leakage-free ----
    // The same fleet planned twice: once on the devices as configured
    // (voltage tables + exponential leakage, DESIGN.md §15) and once on
    // copies with the voltage-dependent excess zeroed. The delta is the
    // energy the v2 term adds to the bill, and the per-assignment split
    // says how much of the v2 plan is leakage.
    bench::section("Power model: v2 (leakage-aware) vs leakage-free plan");
    let lean_registry = Arc::new(DeviceRegistry::new());
    let mut lean_primary = None;
    for rec in &records {
        let id = lean_registry.register(&rec.name, rec.hw, rec.power.without_leakage());
        if rec.id == primary {
            lean_primary = Some(id);
        }
    }
    let lean_primary = lean_primary.expect("primary device re-registered");
    let lean_engine = Engine::native(hw)
        .with_handles(Arc::clone(&lean_registry), Arc::clone(&catalog), lean_primary)
        .expect("attach handles");
    let lean = plan(&lean_engine, &jobs, &cfg).expect("leakage-free fleet is plannable");
    assert_eq!(lean.deadline_violations(&jobs), 0, "same runtimes, same deadlines");
    let v2_leakage_mj: f64 = planned
        .assignments
        .iter()
        .map(|a| a.power_leakage_w * a.time_us * 1e-3)
        .sum();
    let v2_dynamic_mj: f64 = planned
        .assignments
        .iter()
        .map(|a| a.power_dynamic_w * a.time_us * 1e-3)
        .sum();
    let v1_v2_delta_mj = planned.total_energy_mj - lean.total_energy_mj;
    println!(
        "v2 {:.1} mJ ({v2_dynamic_mj:.1} dynamic + {v2_leakage_mj:.1} leakage) vs \
         leakage-free {:.1} mJ ({v1_v2_delta_mj:+.1} mJ)",
        planned.total_energy_mj, lean.total_energy_mj
    );
    assert!(
        planned.total_energy_mj >= lean.total_energy_mj,
        "zeroing the leakage term must never raise the optimal fleet energy"
    );

    let out = Value::obj(vec![
        ("bench", Value::str("planner_fleet")),
        ("jobs", Value::num(jobs.len() as f64)),
        ("devices", Value::num(records.len() as f64)),
        ("device_cap", Value::num(cap as f64)),
        ("planned_energy_mj", Value::num(planned.total_energy_mj)),
        ("baseline_energy_mj", Value::num(naive.total_energy_mj)),
        ("energy_savings_pct", Value::num(saved_pct)),
        ("deadline_violations", Value::num(violations as f64)),
        (
            "baseline_deadline_violations",
            Value::num(naive.deadline_violations(&jobs) as f64),
        ),
        ("swaps_applied", Value::num(planned.swaps_applied as f64)),
        ("solve_mean_ms", Value::num(solve.mean_ns / 1e6)),
        ("solve_p50_ms", Value::num(solve.p50_ns / 1e6)),
        ("solve_p99_ms", Value::num(solve.p99_ns / 1e6)),
        ("solve_telemetry_off_mean_ms", Value::num(solve_off.mean_ns / 1e6)),
        ("telemetry_ratio", Value::num(telemetry_ratio)),
        ("table_tuples", Value::num(tuples_per_pass as f64)),
        ("scalar_tuples_per_s", Value::num(scalar_tuples_per_s)),
        ("soa_tuples_per_s", Value::num(soa_tuples_per_s)),
        ("soa_speedup", Value::num(soa_speedup)),
        ("power_v2_energy_mj", Value::num(planned.total_energy_mj)),
        ("power_v2_dynamic_mj", Value::num(v2_dynamic_mj)),
        ("power_v2_leakage_mj", Value::num(v2_leakage_mj)),
        ("power_leakage_free_energy_mj", Value::num(lean.total_energy_mj)),
        ("power_v1_v2_delta_mj", Value::num(v1_v2_delta_mj)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_planner.json");
    std::fs::write(&path, out.render() + "\n").expect("write BENCH_planner.json");
    println!("wrote {}", path.display());
}
