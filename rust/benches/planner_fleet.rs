//! Fleet-planner bench + gate (DESIGN.md §11): plan a synthetic fleet
//! of 240 jobs over every registered device (the two `configs/*.toml`
//! GPUs, parameters measured per device by the §IV probes) and assert
//! the planner strictly beats the run-everything-at-max-frequency
//! baseline on total energy while meeting **every** deadline. Timings
//! and totals land in `BENCH_planner.json` at the repo root.

use std::sync::Arc;

use gpufreq::engine::Engine;
use gpufreq::model::KernelCounters;
use gpufreq::planner::{plan, plan_with_baseline, Job, PlannerConfig};
use gpufreq::registry::{DeviceRegistry, KernelCatalog, KernelId};
use gpufreq::service::json::Value;
use gpufreq::util::bench;

const FLEET_JOBS: usize = 240;

/// Synthetic kernel mix: the index sweeps memory-boundedness (l2 hit
/// rate, transaction count) and compute intensity, so the fleet spans
/// the paper's regimes and device/frequency choice genuinely matters.
fn counters(i: usize) -> KernelCounters {
    KernelCounters {
        l2_hr: (i % 10) as f64 / 10.0,
        gld_trans: 4.0 + (i % 12) as f64,
        avr_inst: 0.5 + 12.0 * (i % 5) as f64,
        n_blocks: 256.0,
        wpb: 8.0,
        aw: 64.0,
        n_sm: 16.0,
        o_itrs: 8.0,
        i_itrs: (i % 16) as f64,
        uses_smem: i % 3 == 0,
        smem_conflict: 1.0 + (i % 4) as f64,
        gld_body: 4.0 + (i % 12) as f64,
        gld_edge: (i % 8) as f64,
        mem_ops: 1.0 + (i % 4) as f64,
        l1_hr: 0.0,
    }
}

fn main() {
    bench::section("Planner fleet: registry setup (per-device §IV probes)");
    let configs = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let registry = Arc::new(DeviceRegistry::new());
    let primary = registry
        .register_from_config(&configs.join("gtx980.toml"))
        .expect("register gtx980");
    registry
        .register_from_config(&configs.join("gtx960.toml"))
        .expect("register gtx960");
    let records = registry.list();
    println!("registered {} devices", records.len());
    assert!(records.len() >= 2, "the fleet needs every configs/*.toml device");

    let catalog = Arc::new(KernelCatalog::new());
    let kernel_ids: Vec<KernelId> =
        (0..8).map(|i| catalog.register(&format!("synth-{i}"), counters(i * 7 + 1))).collect();

    let hw = registry.get(primary).expect("registered").hw;
    let engine = Engine::native(hw)
        .with_handles(Arc::clone(&registry), Arc::clone(&catalog), primary)
        .expect("attach handles");

    // Deadlines must be meetable on ANY device (so zero violations is a
    // planner guarantee, not luck): budget = headroom × the job's
    // worst-device runtime at max frequency. headroom cycles through
    // tight/medium/loose; a third of the fleet runs unconstrained.
    let max_point = |power: &gpufreq::dvfs::PowerModel| {
        let core = power.core_curve.points.last().expect("non-empty curve").0;
        let mem = power.mem_curve.points.last().expect("non-empty curve").0;
        gpufreq::registry::FreqPoint::new(core, mem)
    };
    let mut worst_max_us = vec![0.0f64; kernel_ids.len()];
    for (ki, &kid) in kernel_ids.iter().enumerate() {
        for rec in &records {
            let t = engine
                .predict_handle(rec.id, kid, max_point(&rec.power))
                .expect("predict at max frequency")
                .time_us;
            worst_max_us[ki] = worst_max_us[ki].max(t);
        }
    }

    let headrooms = [1.1, 1.5, 2.5];
    let jobs: Vec<Job> = (0..FLEET_JOBS)
        .map(|i| {
            let ki = i % kernel_ids.len();
            let scale = 1.0 + (i % 7) as f64;
            let job = Job::new(format!("job-{i}"), kernel_ids[ki], scale);
            if i % 3 == 2 {
                job
            } else {
                let headroom = headrooms[(i / 3) % headrooms.len()];
                job.with_deadline(headroom * scale * worst_max_us[ki])
            }
        })
        .collect();
    assert!(jobs.len() >= 200, "the gate is defined over a >=200 job fleet");

    // Balanced per-device concurrency cap.
    let cap = jobs.len().div_ceil(records.len());
    let cfg = PlannerConfig { device_cap: cap, ..PlannerConfig::default() };

    bench::section(&format!(
        "Planner fleet: {} jobs x {} devices (cap {cap}/device)",
        jobs.len(),
        records.len()
    ));
    // Warm pass outside the timer primes the engine's grid cache and
    // produces the plan under test (one evaluation pass covers the
    // baseline too).
    let (planned, naive) = plan_with_baseline(&engine, &jobs, &cfg).expect("plannable fleet");
    let naive = naive.expect("round-robin baseline is placeable under a balanced cap");
    let solve = bench::bench("plan (warm engine cache)", 1, 10, || {
        std::hint::black_box(plan(&engine, &jobs, &cfg).expect("plannable"));
    });

    // ---- The gate ----
    let violations = planned.deadline_violations(&jobs);
    assert_eq!(violations, 0, "an emitted plan must meet every deadline");
    assert!(
        planned.total_energy_mj < naive.total_energy_mj,
        "planner energy {} mJ must be strictly below the max-frequency baseline {} mJ",
        planned.total_energy_mj,
        naive.total_energy_mj
    );
    for rec in &records {
        let load = planned.load_of(rec.id);
        assert!(load <= cap, "cap violated on {}: {load} > {cap}", rec.id);
    }
    let saved_pct = planned.energy_savings_pct_vs(&naive);
    println!(
        "plan {:.1} mJ vs baseline {:.1} mJ ({saved_pct:.1}% saved, {} local-search steps, \
         0 violations)",
        planned.total_energy_mj, naive.total_energy_mj, planned.swaps_applied
    );
    let cache = engine.cache_stats();
    println!(
        "engine cache: {} hits / {} misses ({} entries)",
        cache.hits, cache.misses, cache.entries
    );

    let out = Value::obj(vec![
        ("bench", Value::str("planner_fleet")),
        ("jobs", Value::num(jobs.len() as f64)),
        ("devices", Value::num(records.len() as f64)),
        ("device_cap", Value::num(cap as f64)),
        ("planned_energy_mj", Value::num(planned.total_energy_mj)),
        ("baseline_energy_mj", Value::num(naive.total_energy_mj)),
        ("energy_savings_pct", Value::num(saved_pct)),
        ("deadline_violations", Value::num(violations as f64)),
        (
            "baseline_deadline_violations",
            Value::num(naive.deadline_violations(&jobs) as f64),
        ),
        ("swaps_applied", Value::num(planned.swaps_applied as f64)),
        ("solve_mean_ms", Value::num(solve.mean_ns / 1e6)),
        ("solve_p50_ms", Value::num(solve.p50_ns / 1e6)),
        ("solve_p99_ms", Value::num(solve.p99_ns / 1e6)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_planner.json");
    std::fs::write(&path, out.render() + "\n").expect("write BENCH_planner.json");
    println!("wrote {}", path.display());
}
