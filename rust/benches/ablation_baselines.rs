//! Ablation A1 (DESIGN.md §5): the paper's queueing model vs the
//! frequency-unaware and heuristic baselines its related-work section
//! argues against, on identical one-shot profiles.

use gpufreq::baselines::standard_baselines;
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::util::bench;

fn main() {
    let spec = GpuSpec::default();
    let ex = microbench::extract(&spec, Clocks::new(700.0, 700.0));
    let pairs = microbench::standard_grid();
    let ks = kernels::all();

    bench::section("Ablation: predictor MAPE over the full grid");
    let rows = tables::run_ablation(&spec, &ks, ex.hw, standard_baselines(ex.hw), &pairs);
    print!("{}", tables::ablation(&rows).ascii());

    let paper = rows.iter().find(|(n, _, _)| n == "paper").unwrap().1;
    for (name, mape, _) in &rows {
        if name != "paper" {
            assert!(
                *mape > paper,
                "{name} ({:.2}%) should not beat the paper model ({:.2}%)",
                mape * 100.0,
                paper * 100.0
            );
        }
    }
    println!(
        "\nthe frequency-aware queueing model wins; const-latency collapses whenever the\n\
         memory clock moves (the paper's core argument, §IV).\n"
    );

    bench::bench("ablation (4 predictors x 12 kernels x 49 pairs)", 0, 1, || {
        std::hint::black_box(tables::run_ablation(
            &spec,
            &ks,
            ex.hw,
            standard_baselines(ex.hw),
            &pairs,
        ));
    });

    // --- A3b: the §VII future-work ablation -------------------------
    // The TEX kernel routes its loads through the texture/L1 cache the
    // published model ignores; the L1-extended model repairs it.
    bench::section("Ablation: texture/L1 future work (TEX kernel)");
    let l1_lat =
        gpufreq::microbench::l1_latency_probe(&spec, gpufreq::sim::Clocks::new(700.0, 700.0));
    let tex = vec![gpufreq::kernels::texture_filter()];
    let l1_preds: Vec<Box<dyn gpufreq::baselines::Predictor>> = vec![
        Box::new(gpufreq::baselines::PaperModel { hw: ex.hw }),
        Box::new(gpufreq::baselines::L1Extended::new(ex.hw, l1_lat)),
    ];
    let rows = tables::run_ablation(&spec, &tex, ex.hw, l1_preds, &pairs);
    print!("{}", tables::ablation(&rows).ascii());
    let paper_tex = rows.iter().find(|(n, _, _)| n == "paper").unwrap().1;
    let ext_tex = rows.iter().find(|(n, _, _)| n == "paper+l1").unwrap().1;
    assert!(ext_tex < paper_tex, "L1 extension must reduce TEX error");
    println!(
        "\nTEX (l1-routed loads): published model {:.1}% MAPE -> L1-extended {:.1}%\n\
         (the error the paper's §VII predicts, and the extension that repairs it).\n",
        paper_tex * 100.0,
        ext_tex * 100.0
    );
}
