"""Pure-jnp oracle for the batched frequency-scaling performance model.

This file is the single source of truth for the model math on the Python
side; the Pallas kernel in ``perfmodel.py`` must match it bit-for-bit (both
are f32), and the scalar Rust implementation in ``rust/src/model`` mirrors
the same equations (cross-checked by an integration test through the AOT
artifact).

Equations implemented (numbers follow the paper):

* Eq. (4)   dm_lat(cf, mf) = dm_lat_a * cf/mf + dm_lat_b
* Eq. (5a)  agl_lat = l2_lat * l2_hr + dm_lat * (1 - l2_hr)
* Eq. (5b)  agl_del = l2_del * l2_hr + dm_del * cf/mf * (1 - l2_hr)
* Eq. (7)   avr_comp = inst_cycle * avr_inst
* Eqs. (8)-(15)  the four no-shared-memory regimes
* Eqs. (16)-(21) the two shared-memory regimes
* Eq. (6)   T_exec = T_active * round count

Deviations from the paper as printed (documented in DESIGN.md §2):

* Eq. (5a) composes Eq. (4) directly instead of multiplying a baseline
  dm_lat by cf/mf a second time (the paper's notation double-counts the
  ratio if read literally).
* The queue-drain terms use ``agl_del * gld_trans`` (per-warp transactions
  fold into the queue time); the paper's pipeline figures draw
  gld_trans = 1 per iteration, where the two readings coincide.
* Eq. (11) as printed multiplies by #Wpb where every analogous equation
  (3), (17), (18) uses the number of queued warps; we use #Aw.
* Conditions (10b)/(12b) as printed select the *opposite* regimes from
  the pipeline figures they describe: the queue stays saturated (Fig. 7,
  Eq. 11) when a warp's turnaround time `avr_comp + agl_lat` is SHORTER
  than the queue-drain time of the other warps `agl_del*gld*(#Aw-1)` —
  with many active warps the drain time is huge and Eq. 11 must apply,
  yet the printed `>=` sends that case to Eq. 13. We use the direction
  consistent with Figs. 7/8 (validated against the simulator).
* The paper's `o_itrs` counts (compute, one-transaction) periods, ours
  counts source-level loop iterations; the per-iteration compute period
  is therefore `C = avr_comp * gld_trans` in the time formulas (they
  coincide at gld_trans = 1, the case the figures draw).
* Eq. (19) (smem-intensive phase 2) models a single block pipelining
  through the SM; with several resident blocks the ALU, the smem ports
  and the MC serialize across blocks, so phase 2 takes the binding
  resource: max(ALU serialization, smem-port serialization, body queue
  drain) plus the barrier-exposed latency chain. Boundary
  (prologue/epilogue) traffic drains while other blocks compute, so the
  total is max(body, edge) rather than a sum. Reduces to the paper's
  form when one block dominates.
* In the latency-exposed regimes (Eqs. 13/15) each of the `mem_ops`
  dependent memory instructions in an iteration pays a full `agl_lat`;
  transactions inside one instruction pipeline through the LSU.
"""

from __future__ import annotations

import jax.numpy as jnp

# Feature column indices for the (N, 12) feature matrix.
F_L2_HR = 0  # L2 hit rate in [0, 1]
F_GLD_TRANS = 1  # global transactions per warp per outer iteration
F_AVR_INST = 2  # compute instructions per global transaction
F_N_BLOCKS = 3  # #B
F_WPB = 4  # #Wpb, warps per block
F_AW = 5  # #Aw, active warps per SM
F_N_SM = 6  # #SM (active)
F_O_ITRS = 7  # outer iterations
F_I_ITRS = 8  # inner (shared-memory) iterations
F_USES_SMEM = 9  # 0.0 / 1.0 flag
F_CORE_F = 10  # MHz
F_MEM_F = 11  # MHz
F_SMEM_CONFLICT = 12  # average bank-conflict degree (1 = conflict-free)
F_GLD_BODY = 13  # global txns per warp per iter inside the body loop
F_GLD_EDGE = 14  # global txns per warp in prologue + epilogue
F_MEM_OPS = 15  # dependent global-memory instructions per body iter
N_FEATURES = 16

# Hardware-parameter indices for the (7,) vector.
H_DM_LAT_A = 0  # Eq. (4) slope, core cycles per unit cf/mf
H_DM_LAT_B = 1  # Eq. (4) intercept, core cycles
H_DM_DEL = 2  # DRAM service per transaction, memory cycles
H_L2_LAT = 3  # L2 hit latency, core cycles
H_L2_DEL = 4  # L2 service per transaction, core cycles
H_SH_LAT = 5  # shared-memory latency, core cycles
H_INST_CYCLE = 6  # cycles per compute instruction
N_HW_PARAMS = 7

# Output column indices for the (N, 4) result.
O_T_ACTIVE = 0  # cycles for one round of active warps
O_T_EXEC = 1  # total kernel cycles (core domain)
O_TIME_US = 2  # wall-clock microseconds
O_REGIME = 3  # regime id, see REGIME_*
N_OUTPUTS = 4

REGIME_COMPUTE = 0.0  # Eq. (9)
REGIME_FEW_LONG = 1.0  # Eq. (15)
REGIME_MEMORY = 2.0  # Eq. (11)
REGIME_FEW_SHORT = 3.0  # Eq. (13)
REGIME_SMEM_LIGHT = 4.0  # Eq. (17)
REGIME_SMEM_INTENSE = 5.0  # Eq. (21)


def predict_ref(features: jnp.ndarray, hw: jnp.ndarray) -> jnp.ndarray:
    """Evaluate the model for a batch of samples.

    Args:
      features: (N, 12) f32, columns per ``F_*``.
      hw: (7,) f32, entries per ``H_*``.

    Returns:
      (N, 4) f32, columns per ``O_*``.
    """
    f = features.astype(jnp.float32)
    l2_hr = f[:, F_L2_HR]
    gld_trans = f[:, F_GLD_TRANS]
    avr_inst = f[:, F_AVR_INST]
    n_blocks = f[:, F_N_BLOCKS]
    wpb = f[:, F_WPB]
    aw = f[:, F_AW]
    n_sm = f[:, F_N_SM]
    o_itrs = f[:, F_O_ITRS]
    i_itrs = f[:, F_I_ITRS]
    uses_smem = f[:, F_USES_SMEM]
    core_f = f[:, F_CORE_F]
    mem_f = f[:, F_MEM_F]
    smem_conflict = f[:, F_SMEM_CONFLICT]
    gld_body = f[:, F_GLD_BODY]
    gld_edge = f[:, F_GLD_EDGE]
    mem_ops = f[:, F_MEM_OPS]

    hw = hw.astype(jnp.float32)
    dm_lat_a = hw[H_DM_LAT_A]
    dm_lat_b = hw[H_DM_LAT_B]
    dm_del = hw[H_DM_DEL]
    l2_lat = hw[H_L2_LAT]
    l2_del = hw[H_L2_DEL]
    sh_lat = hw[H_SH_LAT]
    inst_cycle = hw[H_INST_CYCLE]

    ratio = core_f / mem_f
    dm_lat = dm_lat_a * ratio + dm_lat_b  # Eq. (4)
    miss = 1.0 - l2_hr
    agl_lat = l2_lat * l2_hr + dm_lat * miss  # Eq. (5a)
    agl_del = l2_del * l2_hr + dm_del * ratio * miss  # Eq. (5b)
    avr_comp = inst_cycle * avr_inst  # Eq. (7b), per transaction
    comp_iter = avr_comp * gld_trans  # per body iteration ("C")

    # Queue time contributed by one warp in one outer iteration.
    q = agl_del * gld_trans

    # --- no-shared-memory regimes ------------------------------------
    # Per-iteration exposed latency: each dependent memory instruction
    # pays a full agl_lat when nothing hides it (see module docstring).
    lat_iter = agl_lat * jnp.maximum(mem_ops, 1.0)
    t9 = comp_iter * aw * o_itrs + agl_lat
    t15 = comp_iter * (aw - 1.0) + (comp_iter + lat_iter) * o_itrs
    t11 = agl_lat + comp_iter + q * aw * o_itrs
    t13 = q * aw + agl_lat + comp_iter + (comp_iter + lat_iter) * (o_itrs - 1.0)

    comp_bound = avr_comp >= agl_del  # Eq. (8a) / (14a)
    hides_lat = comp_iter * (aw - 1.0) >= lat_iter  # Eq. (8b) vs (14b)
    # Queue stays saturated when warp turnaround < other-warp drain time
    # (direction per Figs. 7/8; the printed (10b)/(12b) are swapped —
    # see module docstring).
    queue_sat = (comp_iter + agl_lat) <= q * (aw - 1.0)

    t_comp = jnp.where(hides_lat, t9, t15)
    r_comp = jnp.where(hides_lat, REGIME_COMPUTE, REGIME_FEW_LONG)
    t_mem = jnp.where(queue_sat, t11, t13)
    r_mem = jnp.where(queue_sat, REGIME_MEMORY, REGIME_FEW_SHORT)
    t_nosmem = jnp.where(comp_bound, t_comp, t_mem)
    r_nosmem = jnp.where(comp_bound, r_comp, r_mem)

    # --- shared-memory regimes ---------------------------------------
    t17 = comp_iter + agl_lat + q * aw * o_itrs  # Eq. (17)
    # Refined Eqs. (18)-(21): phase 2 takes the binding resource and the
    # body overlaps the boundary drain (see module docstring).
    q_body = agl_del * gld_body
    alu = comp_iter * aw
    port = i_itrs * smem_conflict * aw
    mem_iter = q_body * aw  # Eq. (20): body queue drain
    chain = sh_lat * i_itrs  # barrier-exposed latency
    body = (jnp.maximum(jnp.maximum(alu, port), mem_iter) + chain) * o_itrs
    edge = agl_del * gld_edge * aw  # Eq. (18): boundary drain
    t21 = jnp.maximum(body, edge) + agl_lat + sh_lat  # Eq. (21)

    smem_light = jnp.logical_and(
        avr_comp <= agl_del,  # Eq. (16a)
        (avr_comp + sh_lat) < q_body * (aw - wpb),  # Eq. (16b)
    )
    t_smem = jnp.where(smem_light, t17, t21)
    r_smem = jnp.where(smem_light, REGIME_SMEM_LIGHT, REGIME_SMEM_INTENSE)

    has_smem = uses_smem > 0.5
    t_active = jnp.where(has_smem, t_smem, t_nosmem)
    regime = jnp.where(has_smem, r_smem, r_nosmem)

    # --- Eq. (6) -------------------------------------------------------
    rounds = jnp.maximum(wpb * n_blocks / (aw * n_sm), 1.0)
    t_exec = t_active * rounds
    time_us = t_exec / core_f  # cycles at core_f MHz -> microseconds

    return jnp.stack([t_active, t_exec, time_us, regime], axis=1)


def fit_dm_lat_ref(ratios: jnp.ndarray, lats: jnp.ndarray) -> jnp.ndarray:
    """Least-squares fit of Eq. (4): lat = a * ratio + b.

    Returns (3,) f32: [a, b, r_squared].
    """
    x = ratios.astype(jnp.float32)
    y = lats.astype(jnp.float32)
    xm = jnp.mean(x)
    ym = jnp.mean(y)
    sxx = jnp.sum((x - xm) ** 2)
    sxy = jnp.sum((x - xm) * (y - ym))
    a = sxy / sxx
    b = ym - a * xm
    resid = y - (a * x + b)
    ss_res = jnp.sum(resid**2)
    ss_tot = jnp.sum((y - ym) ** 2)
    r2 = 1.0 - ss_res / ss_tot
    return jnp.stack([a, b, r2])
