"""L1 Pallas kernel: batched piecewise performance-model evaluator.

One grid step evaluates ``BLOCK`` samples of the (N, 12) feature matrix
against the shared (1, 7) hardware-parameter row and writes a (BLOCK, 4)
output tile. Regime selection (the paper's Eqs. 8/10/12/14/16 conditions)
is branchless: all six regime times are computed vectorized and folded with
``jnp.where`` masks, so the kernel is a single fused elementwise region —
no gather/scatter, no divergence.

TPU notes (DESIGN.md §3 "Hardware adaptation"): a (256, 12) f32 feature
tile + (256, 4) output tile is ~16 KiB, far under VMEM; the arithmetic is
purely elementwise over the sample axis (VPU work, no MXU). ``interpret=True``
is mandatory here — the CPU PJRT client cannot execute Mosaic custom calls;
the lowered HLO is plain elementwise ops that any backend runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 256  # samples per grid step; (BLOCK, 12) f32 tile = 12 KiB


def _perfmodel_kernel(features_ref, hw_ref, out_ref):
    """Pallas kernel body. Shapes: (BLOCK, 12), (1, 7), (BLOCK, 4)."""
    f = features_ref[...]
    hw = hw_ref[...]

    l2_hr = f[:, ref.F_L2_HR]
    gld_trans = f[:, ref.F_GLD_TRANS]
    avr_inst = f[:, ref.F_AVR_INST]
    n_blocks = f[:, ref.F_N_BLOCKS]
    wpb = f[:, ref.F_WPB]
    aw = f[:, ref.F_AW]
    n_sm = f[:, ref.F_N_SM]
    o_itrs = f[:, ref.F_O_ITRS]
    i_itrs = f[:, ref.F_I_ITRS]
    uses_smem = f[:, ref.F_USES_SMEM]
    core_f = f[:, ref.F_CORE_F]
    mem_f = f[:, ref.F_MEM_F]
    smem_conflict = f[:, ref.F_SMEM_CONFLICT]
    gld_body = f[:, ref.F_GLD_BODY]
    gld_edge = f[:, ref.F_GLD_EDGE]
    mem_ops = f[:, ref.F_MEM_OPS]

    dm_lat_a = hw[0, ref.H_DM_LAT_A]
    dm_lat_b = hw[0, ref.H_DM_LAT_B]
    dm_del = hw[0, ref.H_DM_DEL]
    l2_lat = hw[0, ref.H_L2_LAT]
    l2_del = hw[0, ref.H_L2_DEL]
    sh_lat = hw[0, ref.H_SH_LAT]
    inst_cycle = hw[0, ref.H_INST_CYCLE]

    ratio = core_f / mem_f
    dm_lat = dm_lat_a * ratio + dm_lat_b  # Eq. (4)
    miss = 1.0 - l2_hr
    agl_lat = l2_lat * l2_hr + dm_lat * miss  # Eq. (5a)
    agl_del = l2_del * l2_hr + dm_del * ratio * miss  # Eq. (5b)
    avr_comp = inst_cycle * avr_inst  # Eq. (7b), per transaction
    comp_iter = avr_comp * gld_trans  # per body iteration ("C")
    q = agl_del * gld_trans

    lat_iter = agl_lat * jnp.maximum(mem_ops, 1.0)
    t9 = comp_iter * aw * o_itrs + agl_lat
    t15 = comp_iter * (aw - 1.0) + (comp_iter + lat_iter) * o_itrs
    t11 = agl_lat + comp_iter + q * aw * o_itrs
    t13 = q * aw + agl_lat + comp_iter + (comp_iter + lat_iter) * (o_itrs - 1.0)

    comp_bound = avr_comp >= agl_del
    hides_lat = comp_iter * (aw - 1.0) >= lat_iter
    # Direction per Figs. 7/8 — see ref.py docstring on (10b)/(12b).
    queue_sat = (comp_iter + agl_lat) <= q * (aw - 1.0)

    t_comp = jnp.where(hides_lat, t9, t15)
    r_comp = jnp.where(hides_lat, ref.REGIME_COMPUTE, ref.REGIME_FEW_LONG)
    t_mem = jnp.where(queue_sat, t11, t13)
    r_mem = jnp.where(queue_sat, ref.REGIME_MEMORY, ref.REGIME_FEW_SHORT)
    t_nosmem = jnp.where(comp_bound, t_comp, t_mem)
    r_nosmem = jnp.where(comp_bound, r_comp, r_mem)

    t17 = comp_iter + agl_lat + q * aw * o_itrs
    # Refined Eqs. (18)-(21) — see ref.py docstring.
    q_body = agl_del * gld_body
    alu = comp_iter * aw
    port = i_itrs * smem_conflict * aw
    mem_iter = q_body * aw
    chain = sh_lat * i_itrs
    body = (jnp.maximum(jnp.maximum(alu, port), mem_iter) + chain) * o_itrs
    edge = agl_del * gld_edge * aw
    t21 = jnp.maximum(body, edge) + agl_lat + sh_lat

    smem_light = jnp.logical_and(
        avr_comp <= agl_del,
        (avr_comp + sh_lat) < q_body * (aw - wpb),
    )
    t_smem = jnp.where(smem_light, t17, t21)
    r_smem = jnp.where(smem_light, ref.REGIME_SMEM_LIGHT, ref.REGIME_SMEM_INTENSE)

    has_smem = uses_smem > 0.5
    t_active = jnp.where(has_smem, t_smem, t_nosmem)
    regime = jnp.where(has_smem, r_smem, r_nosmem)

    rounds = jnp.maximum(wpb * n_blocks / (aw * n_sm), 1.0)
    t_exec = t_active * rounds
    time_us = t_exec / core_f

    out_ref[...] = jnp.stack([t_active, t_exec, time_us, regime], axis=1)


def predict(features: jnp.ndarray, hw: jnp.ndarray) -> jnp.ndarray:
    """Batched model evaluation through the Pallas kernel.

    Args:
      features: (N, 12) f32 with N a multiple of ``BLOCK`` (the L2 wrapper
        in ``model.py`` pads arbitrary N).
      hw: (7,) f32 hardware parameters.

    Returns:
      (N, 4) f32 per ``ref.O_*``.
    """
    n = features.shape[0]
    if n % BLOCK != 0:
        raise ValueError(f"N={n} must be a multiple of BLOCK={BLOCK}")
    grid = (n // BLOCK,)
    hw2 = hw.reshape(1, ref.N_HW_PARAMS).astype(jnp.float32)
    return pl.pallas_call(
        _perfmodel_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, ref.N_FEATURES), lambda i: (i, 0)),
            pl.BlockSpec((1, ref.N_HW_PARAMS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK, ref.N_OUTPUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ref.N_OUTPUTS), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(features.astype(jnp.float32), hw2)
