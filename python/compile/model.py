"""L2: the JAX prediction pipeline around the L1 Pallas kernel.

Two exported entry points (both AOT-lowered by ``aot.py``):

* ``predict_batch(features, hw)`` — pad-to-block, run the Pallas evaluator,
  slice back. This is the artifact the Rust coordinator executes on its
  hot path (``artifacts/perf_model.hlo.txt``).
* ``fit_dm_lat(ratios, lats)`` — least-squares fit of Eq. (4) from
  micro-benchmark samples (``artifacts/fit_dm_lat.hlo.txt``), used by the
  Rust microbench pipeline to derive (dm_lat_a, dm_lat_b, R²).

Python never runs at request time; these functions exist to be lowered.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import perfmodel, ref

# The AOT artifact is specialized to a fixed batch shape; the Rust batcher
# packs requests into batches of exactly PREDICT_BATCH rows (padding with
# benign rows — mem_f and core_f of padding rows are 1.0 to avoid div-by-0).
PREDICT_BATCH = 1024
FIT_SAMPLES = 49  # one sample per frequency pair in the standard sweep


def predict_batch(features: jnp.ndarray, hw: jnp.ndarray) -> jnp.ndarray:
    """(PREDICT_BATCH, 12) f32, (7,) f32 -> (PREDICT_BATCH, 4) f32."""
    n = features.shape[0]
    pad = (-n) % perfmodel.BLOCK
    if pad:
        # Benign padding: ratio 1, no div-by-zero, regime irrelevant.
        filler = jnp.ones((pad, ref.N_FEATURES), dtype=jnp.float32)
        features = jnp.concatenate([features.astype(jnp.float32), filler])
    out = perfmodel.predict(features, hw)
    return out[:n]


def fit_dm_lat(ratios: jnp.ndarray, lats: jnp.ndarray) -> jnp.ndarray:
    """(M,) f32, (M,) f32 -> (3,) f32 = [slope, intercept, R^2]."""
    return ref.fit_dm_lat_ref(ratios, lats)
