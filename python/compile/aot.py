"""AOT lowering: jax -> HLO text artifacts for the Rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts (written to --out-dir, default ../artifacts):

* ``perf_model.hlo.txt``  — predict_batch(features (1024, 12), hw (7,))
* ``fit_dm_lat.hlo.txt``  — fit_dm_lat(ratios (49,), lats (49,))
* ``manifest.json``       — shapes + feature/param column map for Rust

Usage: ``cd python && python -m compile.aot [--out-dir DIR]``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text with return_tuple=True."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_predict() -> str:
    feat = jax.ShapeDtypeStruct((model.PREDICT_BATCH, ref.N_FEATURES), jnp.float32)
    hw = jax.ShapeDtypeStruct((ref.N_HW_PARAMS,), jnp.float32)
    return to_hlo_text(jax.jit(lambda f, h: (model.predict_batch(f, h),)).lower(feat, hw))


def lower_fit() -> str:
    v = jax.ShapeDtypeStruct((model.FIT_SAMPLES,), jnp.float32)
    return to_hlo_text(jax.jit(lambda x, y: (model.fit_dm_lat(x, y),)).lower(v, v))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) path for perf_model artifact")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    predict_path = args.out or os.path.join(out_dir, "perf_model.hlo.txt")
    fit_path = os.path.join(out_dir, "fit_dm_lat.hlo.txt")

    text = lower_predict()
    with open(predict_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {predict_path}")

    text = lower_fit()
    with open(fit_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {fit_path}")

    manifest = {
        "predict": {
            "artifact": os.path.basename(predict_path),
            "batch": model.PREDICT_BATCH,
            "n_features": ref.N_FEATURES,
            "n_hw_params": ref.N_HW_PARAMS,
            "n_outputs": ref.N_OUTPUTS,
        },
        "fit_dm_lat": {
            "artifact": os.path.basename(fit_path),
            "samples": model.FIT_SAMPLES,
        },
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
