"""L2 pipeline tests: padding wrapper, dm_lat fitting, AOT lowering."""

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref
from .test_kernel import HW, make_features


def test_predict_batch_pads_and_slices():
    rows = np.stack([make_features(core_f=500.0 + i, mem_f=700.0) for i in range(10)])
    out = np.asarray(model.predict_batch(jnp.asarray(rows), jnp.asarray(HW)))
    want = np.asarray(ref.predict_ref(jnp.asarray(rows), jnp.asarray(HW)))
    assert out.shape == (10, ref.N_OUTPUTS)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_predict_batch_full_batch():
    rows = np.tile(make_features(), (model.PREDICT_BATCH, 1))
    out = np.asarray(model.predict_batch(jnp.asarray(rows), jnp.asarray(HW)))
    assert out.shape == (model.PREDICT_BATCH, ref.N_OUTPUTS)
    # identical rows -> identical predictions
    assert np.allclose(out, out[0])


def test_fit_dm_lat_recovers_paper_line():
    """Feed exact Eq. (4) samples; the fit must recover (222.78, 277.32, 1)."""
    rng = np.random.default_rng(1)
    cf = rng.uniform(400, 1000, size=49).astype(np.float32)
    mf = rng.uniform(400, 1000, size=49).astype(np.float32)
    ratios = cf / mf
    lats = 222.78 * ratios + 277.32
    a, b, r2 = np.asarray(model.fit_dm_lat(jnp.asarray(ratios), jnp.asarray(lats)))
    assert abs(a - 222.78) < 0.05
    assert abs(b - 277.32) < 0.05
    assert r2 > 0.9999


def test_fit_dm_lat_noisy_r2():
    """With ~1% noise R^2 should be high but < 1 (paper reports 0.9959)."""
    rng = np.random.default_rng(2)
    ratios = (rng.uniform(400, 1000, 49) / rng.uniform(400, 1000, 49)).astype(np.float32)
    lats = 222.78 * ratios + 277.32 + rng.normal(0, 5.0, 49).astype(np.float32)
    a, b, r2 = np.asarray(model.fit_dm_lat(jnp.asarray(ratios), jnp.asarray(lats)))
    assert 200 < a < 245
    assert 255 < b < 300
    assert 0.95 < r2 < 1.0


def test_aot_lowering_produces_hlo_text():
    text = aot.lower_predict()
    assert "HloModule" in text
    assert f"f32[{model.PREDICT_BATCH},{ref.N_FEATURES}]" in text
    text = aot.lower_fit()
    assert "HloModule" in text
    assert f"f32[{model.FIT_SAMPLES}]" in text
