"""Pallas kernel vs pure-jnp oracle — the core correctness signal (L1).

Hypothesis sweeps the feature space (hit rates, occupancy, iteration
counts, frequency pairs, smem flags) and asserts the Pallas evaluator
matches ``ref.predict_ref`` to f32 tolerance, plus directed tests that pin
each of the six regimes and the paper's worked numbers (Eq. 4 endpoints).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import perfmodel, ref

HW = np.array([222.78, 277.32, 9.0, 222.0, 1.0, 28.0, 2.0], dtype=np.float32)


def make_features(
    l2_hr=0.2,
    gld_trans=4.0,
    avr_inst=20.0,
    n_blocks=128.0,
    wpb=8.0,
    aw=32.0,
    n_sm=16.0,
    o_itrs=16.0,
    i_itrs=0.0,
    uses_smem=0.0,
    core_f=700.0,
    mem_f=700.0,
    smem_conflict=1.0,
    gld_body=None,
    gld_edge=0.0,
    mem_ops=1.0,
):
    row = np.zeros(ref.N_FEATURES, dtype=np.float32)
    row[ref.F_L2_HR] = l2_hr
    row[ref.F_GLD_TRANS] = gld_trans
    row[ref.F_AVR_INST] = avr_inst
    row[ref.F_N_BLOCKS] = n_blocks
    row[ref.F_WPB] = wpb
    row[ref.F_AW] = aw
    row[ref.F_N_SM] = n_sm
    row[ref.F_O_ITRS] = o_itrs
    row[ref.F_I_ITRS] = i_itrs
    row[ref.F_USES_SMEM] = uses_smem
    row[ref.F_CORE_F] = core_f
    row[ref.F_MEM_F] = mem_f
    row[ref.F_SMEM_CONFLICT] = smem_conflict
    row[ref.F_GLD_BODY] = gld_trans if gld_body is None else gld_body
    row[ref.F_GLD_EDGE] = gld_edge
    row[ref.F_MEM_OPS] = mem_ops
    return row


def run_both(rows):
    feats = np.asarray(rows, dtype=np.float32)
    n = feats.shape[0]
    pad = (-n) % perfmodel.BLOCK
    if pad:
        feats = np.concatenate([feats, np.tile(make_features(), (pad, 1))])
    got = np.asarray(perfmodel.predict(jnp.asarray(feats), jnp.asarray(HW)))
    want = np.asarray(ref.predict_ref(jnp.asarray(feats), jnp.asarray(HW)))
    return got[:n], want[:n]


# ---------------------------------------------------------------- directed


def test_single_block_matches_ref():
    rng = np.random.default_rng(0)
    rows = [
        make_features(
            l2_hr=rng.uniform(0, 1),
            gld_trans=rng.uniform(1, 32),
            avr_inst=rng.uniform(1, 200),
            aw=rng.uniform(2, 64),
            o_itrs=rng.uniform(1, 64),
            core_f=rng.uniform(400, 1000),
            mem_f=rng.uniform(400, 1000),
        )
        for _ in range(perfmodel.BLOCK)
    ]
    got, want = run_both(rows)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-4)


def test_multi_block_grid():
    rows = [make_features(core_f=400 + 100 * (i % 7), mem_f=400 + 100 * (i // 7 % 7)) for i in range(3 * perfmodel.BLOCK)]
    got, want = run_both(rows)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-4)


def test_non_multiple_batch_rejected():
    feats = jnp.zeros((100, ref.N_FEATURES), dtype=jnp.float32)
    with pytest.raises(ValueError):
        perfmodel.predict(feats, jnp.asarray(HW))


@pytest.mark.parametrize(
    "kw,regime",
    [
        # many warps, long compute, low occupancy of memory system
        (dict(avr_inst=500.0, aw=32.0, l2_hr=0.9), ref.REGIME_COMPUTE),
        # long compute but so few warps that latency is exposed
        (
            dict(avr_inst=100.0, gld_trans=1.0, aw=2.0, l2_hr=0.0, mem_ops=2.0),
            ref.REGIME_FEW_LONG,
        ),
        # short compute, many warps -> queue stays saturated (Fig. 7)
        (dict(avr_inst=1.0, gld_trans=16.0, aw=64.0, l2_hr=0.0), ref.REGIME_MEMORY),
        # short compute, few warps -> queue drains between rounds (Fig. 8)
        (dict(avr_inst=1.0, gld_trans=16.0, aw=4.0, l2_hr=0.0), ref.REGIME_FEW_SHORT),
        # smem kernel with tiny smem traffic hidden behind queue
        (
            dict(uses_smem=1.0, avr_inst=1.0, gld_trans=8.0, aw=64.0, wpb=8.0, l2_hr=0.0),
            ref.REGIME_SMEM_LIGHT,
        ),
        # smem-intensive (matrixMul-shared shape)
        (
            dict(uses_smem=1.0, avr_inst=40.0, i_itrs=32.0, aw=16.0, wpb=8.0),
            ref.REGIME_SMEM_INTENSE,
        ),
    ],
)
def test_regime_selection(kw, regime):
    got, want = run_both([make_features(**kw)])
    assert got[0, ref.O_REGIME] == regime, f"kernel regime {got[0, ref.O_REGIME]} != {regime}"
    assert want[0, ref.O_REGIME] == regime, f"ref regime {want[0, ref.O_REGIME]} != {regime}"
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_eq4_endpoints_match_paper():
    """At cf/mf = 1 the modeled dm_lat is ~500.1 cycles (paper Table II row 1);
    at cf/mf = 2.5 it is ~834.3 cycles."""
    # Pure-memory row: l2_hr=0, so agl_lat == dm_lat; few-warps-long-compute
    # regime exposes agl_lat directly is messy — check through ref math.
    for cf, mf, expect in [(400.0, 400.0, 500.10), (1000.0, 400.0, 834.27)]:
        feats = jnp.asarray([make_features(core_f=cf, mem_f=mf)])
        # dm_lat = a*ratio + b
        a, b = HW[ref.H_DM_LAT_A], HW[ref.H_DM_LAT_B]
        assert abs((a * cf / mf + b) - expect) < 0.1
        del feats


def test_time_us_consistency():
    """time_us must equal t_exec / core_f for every sample."""
    rows = [make_features(core_f=cf, mem_f=mf) for cf in (400.0, 700.0, 1000.0) for mf in (400.0, 700.0, 1000.0)]
    got, _ = run_both(rows)
    np.testing.assert_allclose(
        got[:, ref.O_TIME_US],
        got[:, ref.O_T_EXEC] / np.array([r[ref.F_CORE_F] for r in rows]),
        rtol=1e-6,
    )


def test_rounds_floor_at_one():
    """A kernel with fewer blocks than SMs still runs one full round."""
    got, want = run_both([make_features(n_blocks=1.0, wpb=2.0, aw=32.0, n_sm=16.0)])
    np.testing.assert_allclose(got[0, ref.O_T_ACTIVE], got[0, ref.O_T_EXEC], rtol=1e-6)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_memory_bound_speedup_with_mem_freq():
    """A DRAM-bound kernel (l2_hr=0, tiny compute) must speed up ~linearly
    with memory frequency at fixed core frequency (paper Fig. 2a/b)."""
    rows = [
        make_features(l2_hr=0.0, avr_inst=1.0, gld_trans=16.0, aw=64.0, o_itrs=64.0, core_f=1000.0, mem_f=mf)
        for mf in (400.0, 1000.0)
    ]
    got, _ = run_both(rows)
    assert got[0, ref.O_REGIME] == ref.REGIME_MEMORY
    speedup = got[0, ref.O_TIME_US] / got[1, ref.O_TIME_US]
    assert 2.0 < speedup < 2.6, f"memory-bound speedup {speedup}"


def test_compute_bound_insensitive_to_mem_freq():
    """A compute-bound kernel's time must not change with memory frequency
    (paper Fig. 2: MMG/MMS flat vs mem_f at low core_f)."""
    rows = [
        make_features(l2_hr=0.9, avr_inst=500.0, aw=32.0, o_itrs=32.0, core_f=400.0, mem_f=mf)
        for mf in (400.0, 1000.0)
    ]
    got, _ = run_both(rows)
    rel = abs(got[0, ref.O_TIME_US] - got[1, ref.O_TIME_US]) / got[0, ref.O_TIME_US]
    assert rel < 0.02, f"compute-bound drift {rel}"


# ------------------------------------------------------------- hypothesis

feature_strategy = st.fixed_dictionaries(
    dict(
        l2_hr=st.floats(0.0, 1.0, width=32, allow_nan=False),
        gld_trans=st.floats(1.0, 64.0, width=32),
        avr_inst=st.floats(0.5, 1000.0, width=32),
        n_blocks=st.floats(1.0, 4096.0, width=32),
        wpb=st.floats(1.0, 32.0, width=32),
        aw=st.floats(2.0, 64.0, width=32),
        n_sm=st.floats(1.0, 16.0, width=32),
        o_itrs=st.floats(1.0, 512.0, width=32),
        i_itrs=st.floats(0.0, 64.0, width=32),
        uses_smem=st.sampled_from([0.0, 1.0]),
        core_f=st.floats(400.0, 1000.0, width=32),
        mem_f=st.floats(400.0, 1000.0, width=32),
        smem_conflict=st.floats(1.0, 8.0, width=32),
        gld_body=st.floats(0.0, 64.0, width=32),
        gld_edge=st.floats(0.0, 32.0, width=32),
        mem_ops=st.floats(0.0, 8.0, width=32),
    )
)


@settings(max_examples=40, deadline=None)
@given(st.lists(feature_strategy, min_size=1, max_size=16))
def test_hypothesis_kernel_matches_ref(rows):
    got, want = run_both([make_features(**r) for r in rows])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(feature_strategy)
def test_hypothesis_outputs_positive_finite(row):
    got, _ = run_both([make_features(**row)])
    assert np.all(np.isfinite(got))
    assert got[0, ref.O_T_ACTIVE] > 0
    assert got[0, ref.O_T_EXEC] >= got[0, ref.O_T_ACTIVE] * 0.999
    assert got[0, ref.O_TIME_US] > 0


@settings(max_examples=20, deadline=None)
@given(feature_strategy)
def test_hypothesis_mem_freq_monotone_within_regime(row):
    """Raising memory frequency (all else fixed) never slows a kernel, as
    long as it does not cross a regime boundary (the piecewise model is
    only monotone within a regime; boundary jumps are analysed in
    DESIGN.md)."""
    row = dict(row)
    lo = dict(row, mem_f=400.0)
    hi = dict(row, mem_f=1000.0)
    got, _ = run_both([make_features(**lo), make_features(**hi)])
    if got[0, ref.O_REGIME] == got[1, ref.O_REGIME]:
        assert got[1, ref.O_TIME_US] <= got[0, ref.O_TIME_US] * 1.0001
