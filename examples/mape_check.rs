//! Fast calibration probe: native-model MAPE per kernel over the 49-pair
//! grid (the PJRT-backed full_sweep example is the real deliverable).

use gpufreq::baselines::PaperModel;
use gpufreq::coordinator::validate::validate_kernel_with;
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::profiler;
use gpufreq::sim::{Clocks, GpuSpec};

fn main() {
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let ex = microbench::extract(&spec, baseline);
    println!(
        "hw: dm_lat = {:.2}*r + {:.2} (R2 {:.4}), dm_del {:.2}, l2 {:.1}, sh {:.1}, inst {:.2}, eff {:.1}%",
        ex.hw.dm_lat_a,
        ex.hw.dm_lat_b,
        ex.dm_lat_fit.r_squared,
        ex.hw.dm_del,
        ex.hw.l2_lat,
        ex.hw.sh_lat,
        ex.hw.inst_cycle,
        ex.bandwidth_at_baseline.efficiency * 100.0
    );
    let model = PaperModel { hw: ex.hw };
    let pairs = microbench::standard_grid();
    let mut total = 0.0;
    let mut n = 0;
    for k in kernels::all() {
        let prof = profiler::profile(&spec, &k);
        let v = validate_kernel_with(&spec, &k, &prof, &model, &pairs);
        let worst = v
            .points
            .iter()
            .max_by(|a, b| a.abs_err().partial_cmp(&b.abs_err()).unwrap())
            .unwrap();
        println!(
            "{:8} mape {:5.1}%  max {:5.1}% @({},{})  l2hr {:.2} gld {:5.1} avr_inst {:6.2} aw {:2} regime@base {:?}",
            k.name,
            v.mape() * 100.0,
            v.max_abs_err() * 100.0,
            worst.core_mhz,
            worst.mem_mhz,
            prof.counters.l2_hr,
            prof.counters.gld_trans,
            prof.counters.avr_inst,
            prof.counters.aw,
            gpufreq::model::predict(&prof.counters, &ex.hw, 700.0, 700.0).regime,
        );
        total += v.points.iter().map(|p| p.abs_err()).sum::<f64>();
        n += v.points.len();
        if v.mape() > 0.15 {
            for p in v.points.iter().filter(|p| {
                (p.core_mhz == 400.0 || p.core_mhz == 700.0 || p.core_mhz == 1000.0)
                    && (p.mem_mhz == 400.0 || p.mem_mhz == 700.0 || p.mem_mhz == 1000.0)
            }) {
                println!(
                    "    ({:4},{:4}) truth {:9.1}us pred {:9.1}us err {:+6.1}%",
                    p.core_mhz,
                    p.mem_mhz,
                    p.truth_us,
                    p.pred_us,
                    p.signed_err() * 100.0
                );
            }
        }
    }
    println!("OVERALL MAPE {:.2}%", total / n as f64 * 100.0);
}
