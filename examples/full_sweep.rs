//! END-TO-END DRIVER (DESIGN.md §6, EXPERIMENTS.md): the headline run.
//!
//! ```text
//! make artifacts && cargo run --release --example full_sweep
//! ```
//!
//! Exercises every layer of the system on the paper's full workload:
//!
//! 1. **Substrate** — `gpusim` simulates all 12 Table VI kernels at all
//!    49 frequency pairs (ground truth, multi-threaded sweep).
//! 2. **Micro-benchmarks** — the §IV probes extract the hardware
//!    parameters; the Eq. (4) line is fitted through the *AOT PJRT fit
//!    artifact* (L2-lowered least squares), not native code.
//! 3. **Profiler** — each kernel is profiled once at 700/700 MHz.
//! 4. **Prediction** — all 12 x 49 predictions go through the batched
//!    PJRT service executing the Pallas-lowered model artifact
//!    (L3 -> PJRT -> L1; Python is never invoked).
//! 5. **Validation** — Fig. 13 panels, Fig. 14 bars, overall MAPE vs
//!    the paper's 3.5 % headline.

use std::time::{Duration, Instant};

use gpufreq::coordinator::sweep::run_sweep;
use gpufreq::coordinator::validate::{KernelValidation, SamplePoint, Validation};
use gpufreq::engine::{BatchServer, Engine};
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::model::HwParams;
use gpufreq::profiler;
use gpufreq::report::tables;
use gpufreq::sim::{Clocks, GpuSpec};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let pairs = microbench::standard_grid();
    let kernels = kernels::all();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // --- 2. micro-benchmark extraction, Eq. (4) fit via PJRT ----------
    println!("[1/5] micro-benchmarking the simulated GTX 980 ...");
    let (ratios, lats) = microbench::dm_lat_sweep(&spec, &pairs);
    let bw = microbench::bandwidth_probe(&spec, baseline);
    let ratios_f32: Vec<f32> = ratios.iter().map(|&r| r as f32).collect();
    let lats_f32: Vec<f32> = lats.iter().map(|&l| l as f32).collect();
    let rt = gpufreq::runtime::Runtime::load_or_emulated();
    let (slope, intercept, r2) = rt.fit_dm_lat(&ratios_f32, &lats_f32)?;
    drop(rt); // the batch server owns its own executors below
    println!(
        "      dm_lat = {slope:.2}*(cf/mf) + {intercept:.2} core cycles (R² = {r2:.4}; paper 222.78/277.32 @ 0.9959)"
    );
    println!(
        "      dm_del = {:.2} mem cycles, bandwidth efficiency {:.1}% (paper Table III: 76-85%)",
        bw.dm_del_mem_cycles,
        bw.efficiency * 100.0
    );
    let hw = HwParams {
        dm_lat_a: slope,
        dm_lat_b: intercept,
        dm_del: bw.dm_del_mem_cycles,
        l2_lat: microbench::l2_latency_probe(&spec, baseline),
        l2_del: spec.l2_ii_core_cycles,
        sh_lat: microbench::smem_latency_probe(&spec, baseline),
        inst_cycle: microbench::inst_cycle_probe(&spec, baseline),
    };

    // --- 1. ground-truth sweep ----------------------------------------
    println!("[2/5] simulating {} kernels x {} pairs on {workers} workers ...", kernels.len(), pairs.len());
    let t_sweep = Instant::now();
    let sweep = run_sweep(&spec, &kernels, &pairs, workers);
    println!(
        "      {} simulations in {:.1}s",
        sweep.points.len(),
        t_sweep.elapsed().as_secs_f64()
    );

    // --- 3. one-shot profiles ------------------------------------------
    println!("[3/5] profiling each kernel once at 700/700 MHz ...");
    let profiles: Vec<_> =
        kernels.iter().map(|k| profiler::profile_at(&spec, k, baseline)).collect();

    // --- 4. engine-routed batched predictions ---------------------------
    println!("[4/5] predicting through the engine's sharded PJRT service ...");
    let (server, _h) = BatchServer::start_auto(hw.to_f32(), Duration::from_millis(1), workers)?;
    println!(
        "      PJRT platform: {} ({} request shards)",
        server.platform(),
        server.shard_count()
    );
    let engine = Engine::builder(hw).pjrt(server.clone()).build();
    let t_pred = Instant::now();
    let mut per_kernel = Vec::new();
    for (k, p) in kernels.iter().zip(&profiles) {
        let preds = engine.predict_grid(&p.counters, &pairs)?;
        let points = pairs
            .iter()
            .zip(preds)
            .map(|(&(cf, mf), pred)| SamplePoint {
                kernel: k.name.clone(),
                core_mhz: cf,
                mem_mhz: mf,
                truth_us: sweep.time_us(&k.name, cf, mf).expect("swept"),
                pred_us: pred.time_us,
            })
            .collect();
        per_kernel.push(KernelValidation { kernel: k.name.clone(), points });
    }
    let n_preds: usize = per_kernel.iter().map(|k| k.points.len()).sum();
    println!(
        "      {n_preds} predictions in {:.1} ms ({} batches, {:.0}% occupancy)",
        t_pred.elapsed().as_secs_f64() * 1e3,
        server.stats().batches(),
        server.stats().mean_occupancy() * 100.0
    );
    if engine.has_cache() {
        let cs = engine.cache_stats();
        println!(
            "      engine cache: {} misses, {} entries warmed for downstream consumers",
            cs.misses, cs.entries
        );
    }
    let v = Validation { per_kernel };

    // --- 5. report -------------------------------------------------------
    println!("[5/5] validation vs paper\n");
    print!("{}", tables::fig13(&v, Some(400.0), None).ascii());
    print!("{}", tables::fig13(&v, Some(1000.0), None).ascii());
    print!("{}", tables::fig13(&v, None, Some(400.0)).ascii());
    print!("{}", tables::fig13(&v, None, Some(1000.0)).ascii());
    let (chart, summary) = tables::fig14(&v);
    println!("{chart}");
    print!("{}", summary.ascii());
    println!(
        "\nend-to-end: {:.1}s total. Paper headline: 3.5% MAPE, 0.7-6.9% per kernel, 90% of samples < 10%.",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
