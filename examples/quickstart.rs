//! Quickstart: predict one kernel's execution time across DVFS states.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the paper's full workflow on a single kernel (vectorAdd):
//! 1. micro-benchmark the hardware once (§IV) — Eq. (4) fit, dm_del, …
//! 2. profile the kernel once at the 700/700 MHz baseline (§VI-A)
//! 3. predict T_exec at other frequency pairs with the analytical model
//! 4. compare three points against the simulator ground truth.

use gpufreq::microbench;
use gpufreq::model;
use gpufreq::profiler;
use gpufreq::report::Table;
use gpufreq::sim::engine::simulate;
use gpufreq::sim::{Clocks, GpuSpec};
use gpufreq::kernels;

fn main() {
    let spec = GpuSpec::default(); // Table V: GTX 980
    let baseline = Clocks::new(700.0, 700.0);

    // 1. One-time hardware extraction (runs the §IV probes).
    let ex = microbench::extract(&spec, baseline);
    println!(
        "hardware: dm_lat = {:.2}*(cf/mf) + {:.2} cycles (R²={:.4}), dm_del = {:.2} mem cycles\n",
        ex.hw.dm_lat_a, ex.hw.dm_lat_b, ex.dm_lat_fit.r_squared, ex.hw.dm_del
    );

    // 2. One-time kernel profile at the baseline.
    let kernel = kernels::vector_add();
    let profile = profiler::profile_at(&spec, &kernel, baseline);
    println!(
        "profiled {} once at 700/700: l2_hr={:.2}, gld_trans={:.1}, #Aw={:.0}\n",
        profile.kernel, profile.counters.l2_hr, profile.counters.gld_trans, profile.counters.aw
    );

    // 3. Predict across frequency pairs — no further simulation needed.
    let mut t = Table::new(
        "vectorAdd predicted vs simulated",
        &["core MHz", "mem MHz", "predicted µs", "simulated µs", "error"],
    );
    for (cf, mf) in [
        (400.0, 400.0),
        (400.0, 1000.0),
        (700.0, 700.0),
        (1000.0, 400.0),
        (1000.0, 1000.0),
    ] {
        let pred = model::predict(&profile.counters, &ex.hw, cf, mf);
        // 4. Ground truth for comparison.
        let truth = simulate(&spec, Clocks::new(cf, mf), &kernel).stats.elapsed_ns / 1e3;
        t.row(vec![
            format!("{cf:.0}"),
            format!("{mf:.0}"),
            format!("{:.1}", pred.time_us),
            format!("{truth:.1}"),
            format!("{:+.1}%", (pred.time_us - truth) / truth * 100.0),
        ]);
    }
    print!("{}", t.ascii());
    println!("\nNote how memory frequency dominates: vectorAdd is DRAM-bound (paper Fig. 2).");
}
