//! DVFS energy advisor — the application the paper motivates (§I: "even
//! decreasing 5% of the power consumption can reduce up to 1 million
//! dollars") and sketches as future work (§VII).
//!
//! ```text
//! cargo run --release --example dvfs_advisor
//! ```
//!
//! For every Table VI kernel: profile once, then search the 49-pair grid
//! for (a) the minimum-energy configuration, (b) minimum energy within
//! 10 % of peak performance, and report savings vs. running flat-out at
//! 1000/1000 MHz.

use gpufreq::baselines::PaperModel;
use gpufreq::dvfs::{advise, Objective, PowerModel};
use gpufreq::kernels;
use gpufreq::microbench;
use gpufreq::profiler;
use gpufreq::report::Table;
use gpufreq::sim::{Clocks, GpuSpec};

fn main() {
    let spec = GpuSpec::default();
    let baseline = Clocks::new(700.0, 700.0);
    let ex = microbench::extract(&spec, baseline);
    let model = PaperModel { hw: ex.hw };
    let power = PowerModel::gtx980();
    let pairs = microbench::standard_grid();

    let mut t = Table::new(
        "DVFS advisor: per-kernel energy-optimal configurations",
        &[
            "kernel",
            "best cf/mf",
            "energy mJ",
            "vs max-freq",
            "slowdown",
            "10%-slack cf/mf",
            "slack energy mJ",
        ],
    );
    let mut total_savings = 0.0;
    for k in kernels::all() {
        let p = profiler::profile_at(&spec, &k, baseline);
        let (best, points) = advise(&p.counters, &model, &power, &pairs, Objective::Energy);
        let (slack, _) =
            advise(&p.counters, &model, &power, &pairs, Objective::EnergyWithSlack(0.10));
        let max_freq = points
            .iter()
            .find(|c| c.core_mhz == 1000.0 && c.mem_mhz == 1000.0)
            .expect("grid contains 1000/1000");
        let saving = 1.0 - best.energy_mj / max_freq.energy_mj;
        total_savings += saving;
        t.row(vec![
            k.name.clone(),
            format!("{:.0}/{:.0}", best.core_mhz, best.mem_mhz),
            format!("{:.2}", best.energy_mj),
            format!("-{:.0}%", saving * 100.0),
            format!("{:.2}x", best.time_us / max_freq.time_us),
            format!("{:.0}/{:.0}", slack.core_mhz, slack.mem_mhz),
            format!("{:.2}", slack.energy_mj),
        ]);
    }
    print!("{}", t.ascii());
    println!(
        "\nmean energy saving across the suite vs 1000/1000: {:.0}%",
        total_savings / 12.0 * 100.0
    );
    println!("(memory-bound kernels drop core frequency; compute-bound kernels drop memory)");
}
